"""Discrete-event replay: a (strategy, fleet-history) pair -> throughput
timeline, composed from ``core.pipesim.simulate``.

Two primitives make any plan projectable onto any fleet state:

- :func:`feasible_under` — does the strategy's mesh footprint still fit?
- :func:`project_step` — exact pipeline-DAG step simulation with stage times
  rescaled by the *true* device efficiency (vs. the efficiency assumed at
  plan time) and inter-stage comm recomputed from the *true* link bandwidths.

:func:`run_replay` folds an :class:`EventTrace` over a training run.  In
elastic mode the controller consumes each event (its replan downtime is
charged to the wall clock); in static mode the initial plan is kept and
infeasible steps earn zero tokens (checkpoint-restart waiting for the fleet
to recover — the standard non-elastic baseline).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import HeteroCluster, cluster_fingerprint
from repro.core.h1f1b import h1f1b_counts
from repro.core.layering import Layer
from repro.core.pipesim import SimResult, simulate
from repro.core.strategy import ParallelStrategy
from repro.runtime.events import EventTrace, apply_event


# ---------------------------------------------------------------------------
# Projection primitives
# ---------------------------------------------------------------------------


def _true_sub(plan_cluster: HeteroCluster, true_cluster: HeteroCluster,
              cluster_idx: int):
    """The current incarnation of the sub-cluster a stage was planned on
    (matched by name; None if it left the fleet)."""
    name = plan_cluster.subclusters[cluster_idx].name
    for s in true_cluster.subclusters:
        if s.name == name:
            return s
    return None


def feasible_under(strategy: ParallelStrategy, plan_cluster: HeteroCluster,
                   true_cluster: HeteroCluster) -> bool:
    """Does the plan's mesh footprint fit the true fleet?  Per-stage mesh
    shape must fit its sub-cluster, and stages sharing a sub-cluster must
    jointly fit its device count."""
    used: Dict[str, int] = {}
    for s in strategy.stages:
        sub = _true_sub(plan_cluster, true_cluster, s.cluster_idx)
        if sub is None or s.mesh_n > sub.n_nodes or s.mesh_m > sub.devices_per_node:
            return False
        used[sub.name] = used.get(sub.name, 0) + s.n_devices
    for s in true_cluster.subclusters:
        if used.get(s.name, 0) > s.n_devices:
            return False
    return True


def project_step(strategy: ParallelStrategy, plan_cluster: HeteroCluster,
                 true_cluster: HeteroCluster, layers: Sequence[Layer], *,
                 no_overlap: bool = False) -> Optional[SimResult]:
    """Simulate one step of ``strategy`` under the true fleet state.

    Stage compute is rescaled by (efficiency assumed at plan time) /
    (true efficiency); inter-stage comm is recomputed from boundary
    activation bytes over the true links.  Returns None when infeasible.
    """
    if not feasible_under(strategy, plan_cluster, true_cluster):
        return None
    t_f, t_b = [], []
    for s in strategy.stages:
        planned_eff = plan_cluster.subclusters[s.cluster_idx].device.efficiency
        true_eff = _true_sub(plan_cluster, true_cluster,
                             s.cluster_idx).device.efficiency
        scale = planned_eff / true_eff
        t_f.append(s.t_f * scale)
        t_b.append(s.t_b * scale)
    c_links = recompute_c_links(strategy, plan_cluster, true_cluster, layers)
    return simulate(t_f, t_b, c_links, strategy.n_microbatches,
                    strategy.warmup_counts, no_overlap=no_overlap)


def sync_priced_step(strategy: ParallelStrategy, cluster: HeteroCluster,
                     layers: Sequence[Layer], *,
                     no_overlap: bool = False,
                     counts_fn: Optional[Callable] = None) -> SimResult:
    """Referee pricing for planner ablations: simulate one step with the
    per-step data-parallel gradient sync charged (amortized per microbatch)
    to every stage's backward time.

    The joint (``intra_op=True``) search already prices this term — its
    stages carry ``IntraOpPlan.sync_time`` and are left untouched; plans
    from the inter-op-only search get the recomputed charge added, so both
    search modes are compared under the SAME cost accounting (the analogue
    of Fig. 11b's plan-blind-evaluate-real methodology).

    ``counts_fn(t_per_stage, c_links, B) -> warm-up counts`` selects the
    schedule under referee pricing (default H-1F1B) — the api facade passes
    its config's named scheduler here so priced numbers match the lowering.

    Comm-aware plans: a stage whose sync was priced under a *selected*
    collective algorithm (``IntraOpPlan.sync_algo`` set, amortized into
    ``t_b``) keeps the planner's charge — that algorithm is what actually
    runs, so topping it back up to the flat ring would erase a real
    advantage the selection earned.  The flat-ring recompute only applies
    to stages whose search never amortized the sync.
    """
    B = strategy.n_microbatches
    t_b = []
    for s in strategy.stages:
        io = s.intra_op
        if io is not None and io.sync_algo is not None and io.sync_time > 0:
            t_b.append(s.t_b)      # selected-algorithm charge already in t_b
            continue
        sub = cluster.subclusters[s.cluster_idx]
        params = sum(layers[li].param_bytes
                     for li in range(s.layer_start, s.layer_end))
        if s.dp > 1:
            bw = sub.inter_node_bw if s.mesh_n > 1 else sub.intra_node_bw
            sync_mb = params * 2 * (s.dp - 1) / s.dp / bw / B
        else:
            sync_mb = 0.0
        already = io.sync_time if io is not None else 0.0
        t_b.append(s.t_b + max(0.0, sync_mb - already))
    t_f = [s.t_f for s in strategy.stages]
    counts = (counts_fn or h1f1b_counts)(
        [f + b for f, b in zip(t_f, t_b)], strategy.c_links, B)
    return simulate(t_f, t_b, strategy.c_links, B, counts,
                    no_overlap=no_overlap)


def recompute_c_links(strategy: ParallelStrategy, plan_cluster: HeteroCluster,
                      true_cluster: HeteroCluster,
                      layers: Sequence[Layer]) -> List[float]:
    """Inter-stage comm times under the true link bandwidths (boundary
    activation bytes are a property of the layering, not the fleet).

    A comm-aware strategy (``planner_meta["comm"]`` with latency pricing
    on) was searched with the WAN's per-transfer latency in every
    cluster-crossing cut; the recompute keeps that term so retuned warm-up
    counts and projections are priced like the plan itself."""
    meta_comm = strategy.planner_meta.get("comm")
    wan_lat = bool(meta_comm) and meta_comm.get("p2p_latency", True)
    out = []
    for i in range(strategy.n_stages - 1):
        s, nxt = strategy.stages[i], strategy.stages[i + 1]
        cut = layers[s.layer_end - 1].act_out_bytes_per_token * strategy.mb_tokens
        src = _true_sub(plan_cluster, true_cluster, s.cluster_idx)
        dst = _true_sub(plan_cluster, true_cluster, nxt.cluster_idx)
        if src is not None and dst is not None and src.name == dst.name:
            out.append(cut / src.inter_node_bw)
        else:
            out.append(cut / true_cluster.cross_bw
                       + (true_cluster.cross_latency if wan_lat else 0.0))
    return out


# ---------------------------------------------------------------------------
# Replay harness
# ---------------------------------------------------------------------------


@dataclass
class ReplaySample:
    step: int
    wall_s: float            # cumulative wall clock at end of step
    step_time_s: float       # this step's duration (stall time when starved)
    tokens: int              # tokens earned this step (0 during outage)
    events: List[str] = field(default_factory=list)
    decision: Optional[str] = None


@dataclass
class ReplayResult:
    samples: List[ReplaySample]
    tokens_total: int
    wall_total_s: float
    decisions: List = field(default_factory=list)   # ReplanDecision records
    stalled_steps: int = 0
    sim_memo_hits: int = 0      # pipesim-memo traffic across all replans:
    sim_memo_misses: int = 0    # hits/misses summed over `decisions`
    metrics: Dict = field(default_factory=dict)
    # obs.MetricsRegistry snapshot of this run (counters / gauges /
    # histograms — tokens, stalls, per-action decision counts, downtime)

    @property
    def cache_served_replans(self) -> int:
        """Decisions whose simulations were answered entirely from the
        pipesim memo (warm re-plans that never re-solved a schedule)."""
        return sum(1 for d in self.decisions
                   if getattr(d, "sim_memo_hits", 0) > 0
                   and getattr(d, "sim_memo_misses", 0) == 0)

    @property
    def migration_s(self) -> float:
        """Total priced migration downtime charged across all decisions."""
        return sum(getattr(d, "migration_s", 0.0) for d in self.decisions)

    @property
    def search_s(self) -> float:
        """Total plan-search downtime charged across all decisions."""
        return sum(getattr(d, "search_time_s", 0.0) for d in self.decisions)

    @property
    def migration_bytes(self) -> float:
        """Total bytes the adopted plans had to ship (the differ's
        live + checkpoint-restore bound, summed over adoptions)."""
        return sum(getattr(d, "migration_bytes", 0.0) for d in self.decisions)

    def throughput(self) -> float:
        return self.tokens_total / self.wall_total_s if self.wall_total_s else 0.0

    def throughput_between(self, start_step: int, end_step: int) -> float:
        """Average tokens/s over steps in [start_step, end_step)."""
        window = [s for s in self.samples if start_step <= s.step < end_step]
        wall = sum(s.step_time_s for s in window)
        tok = sum(s.tokens for s in window)
        return tok / wall if wall > 0 else 0.0

    def tokens_lost(self, ideal_throughput: float) -> float:
        """Tokens an undisrupted fleet at ``ideal_throughput`` would have
        produced in the same wall time, minus what this run produced."""
        return ideal_throughput * self.wall_total_s - self.tokens_total

    def recovery_latency(self, event_step: int) -> Tuple[int, float]:
        """(#starved steps, seconds) from ``event_step`` until tokens flow
        again — the time-to-recover after a disruption."""
        stalled, secs = 0, 0.0
        seen = False
        for s in self.samples:
            if s.step < event_step:
                continue
            if s.tokens == 0:
                seen = True
                stalled += 1
                secs += s.step_time_s
            elif seen or s.step > event_step:
                break
        return stalled, secs


def run_replay(trace: EventTrace, n_steps: int, *,
               controller=None,
               strategy: Optional[ParallelStrategy] = None,
               plan_cluster: Optional[HeteroCluster] = None,
               layers: Optional[Sequence[Layer]] = None,
               no_overlap: bool = False,
               feed_telemetry: bool = True,
               sink=None) -> ReplayResult:
    """Replay ``trace`` over ``n_steps`` training steps.

    Elastic mode (``controller`` given): events are routed through
    ``controller.handle``; its replan downtime (search + migration) is
    charged to the wall clock at the event step, and measured step times are
    fed back as telemetry.  Static mode (``strategy`` given): the plan never
    changes; steps whose plan does not fit the fleet earn zero tokens and
    burn the last known step time waiting (checkpoint-restart baseline).

    ``sink`` (an ``obs.RunLog`` or anything with ``emit(kind, t, **f)``)
    receives one ``step`` event per step and one ``decision`` event per
    controller decision, stamped with the replay's own wall clock — the
    sim-clock-only invariant of ``repro.obs.sink``.
    """
    elastic = controller is not None
    if elastic:
        if controller.strategy is None:
            controller.bootstrap()
        layers = controller.layers
        true_cluster = controller.cluster
    else:
        assert strategy is not None and plan_cluster is not None \
            and layers is not None, "static replay needs strategy+cluster+layers"
        true_cluster = plan_cluster

    samples: List[ReplaySample] = []
    decisions: List = []
    wall = 0.0
    tokens_total = 0
    stalled_steps = 0
    last_step_time = (controller.strategy if elastic else strategy).est_step_time
    sim_cache: Dict = {}

    def _log_decision(step: int, d) -> None:
        if sink is not None:
            sink.emit("decision", wall, step=step, action=d.action,
                      reason=d.reason, downtime_s=d.downtime_s,
                      search_time_s=d.search_time_s,
                      migration_s=d.migration_s, coalesced=d.coalesced)

    def _log_sample(s: ReplaySample) -> None:
        if sink is not None:
            sink.emit("step", s.wall_s, step=s.step,
                      step_time_s=s.step_time_s, tokens=s.tokens,
                      events=s.events, decision=s.decision)

    for step in range(n_steps):
        evs = trace.at(step)
        ev_names = [e.describe() for e in evs]
        decision_str = None
        for ev in evs:
            if elastic:
                d = controller.handle(ev, step=step)
                decisions.append(d)
                wall += d.downtime_s
                _log_decision(step, d)
                decision_str = d.action if decision_str is None \
                    else f"{decision_str},{d.action}"
            else:
                true_cluster = apply_event(true_cluster, ev)

        if elastic and hasattr(controller, "poll"):
            d = controller.poll(step)
            if d is not None:
                decisions.append(d)
                wall += d.downtime_s
                _log_decision(step, d)
                decision_str = d.action if decision_str is None \
                    else f"{decision_str},{d.action}"

        if elastic:
            strat, pcl = controller.strategy, controller.plan_cluster
            true_cluster = controller.cluster
            if strat is None:
                # checkpoint-restart rung: the fleet holds at the last
                # checkpoint, earning nothing, until planning succeeds
                stalled_steps += 1
                wall += last_step_time
                samples.append(ReplaySample(step, wall, last_step_time, 0,
                                            ev_names, decision_str))
                _log_sample(samples[-1])
                continue
        else:
            strat, pcl = strategy, plan_cluster

        key = (cluster_fingerprint(true_cluster), tuple(strat.warmup_counts),
               tuple((s.layer_start, s.layer_end, s.cluster_idx,
                      s.mesh_n, s.mesh_m) for s in strat.stages))
        if key not in sim_cache:
            res = project_step(strat, pcl, true_cluster, layers,
                               no_overlap=no_overlap)
            sim_cache[key] = res.makespan if res is not None else None
        makespan = sim_cache[key]

        if makespan is None:
            # starved: plan does not fit the fleet; wait one nominal step
            stalled_steps += 1
            wall += last_step_time
            samples.append(ReplaySample(step, wall, last_step_time, 0,
                                        ev_names, decision_str))
            _log_sample(samples[-1])
            continue

        wall += makespan
        last_step_time = makespan
        tok = strat.tokens_per_step()
        tokens_total += tok
        samples.append(ReplaySample(step, wall, makespan, tok,
                                    ev_names, decision_str))
        _log_sample(samples[-1])
        if elastic and feed_telemetry:
            d = controller.on_step_time(step, makespan)
            if d is not None:
                decisions.append(d)
                wall += d.downtime_s
                _log_decision(step, d)

    memo_hits = sum(getattr(d, "sim_memo_hits", 0) for d in decisions)
    memo_misses = sum(getattr(d, "sim_memo_misses", 0) for d in decisions)

    # deterministic metrics digest of the run (obs.metrics snapshot shape)
    from repro.obs.metrics import MetricsRegistry, record_decision
    reg = MetricsRegistry()
    reg.inc("replay.tokens", tokens_total)
    reg.inc("replay.stalled_steps", stalled_steps)
    reg.gauge("replay.steps", n_steps)
    reg.gauge("replay.wall_s", wall)
    reg.gauge("replay.sim_memo_hits", memo_hits)
    reg.gauge("replay.sim_memo_misses", memo_misses)
    for d in decisions:
        record_decision(d, reg)

    return ReplayResult(
        samples, tokens_total, wall, decisions, stalled_steps,
        sim_memo_hits=memo_hits, sim_memo_misses=memo_misses,
        metrics=reg.snapshot())
