"""Online calibration: measured step/stage times -> EWMA efficiency factors.

The planner's cost model predicts per-stage times from ``DeviceProfile``
specs scaled by ``device.efficiency``.  On a live fleet the prediction
drifts — thermal throttling, noisy neighbors, background daemons.  The
calibrator folds measurements back into per-sub-cluster efficiency estimates:

    eff_est = eff_used_at_plan_time * t_predicted / t_measured

EWMA-smoothed per sub-cluster.  ``calibrated(cluster)`` returns a cluster
value with the estimates applied (only when outside the deadband, so noise
does not thrash the plan cache), and ``drift(cluster)`` is the controller's
replan trigger signal.

**Bandwidth tiers** calibrate the same way (:meth:`observe_comm`): a
measured transfer/collective time against its prediction yields a per-tier
bandwidth estimate —

    bw_est = bw_assumed_at_plan_time * t_predicted / t_measured

for the ``"cross"`` WAN link or a named sub-cluster's inter-node fabric.
Since the comm subsystem selects collective algorithms *from* these
bandwidths, a calibrated shift propagates through ``calibrated()`` ->
controller replan -> fresh ``CommModel`` -> re-selected algorithms (e.g. a
congested WAN tips the gradient sync from ring to two-level hierarchical).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import (
    HeteroCluster, set_efficiency, set_inter_node_bw, subcluster_index,
    with_cross_bw,
)
from repro.core.strategy import ParallelStrategy

CROSS = "cross"        # the tier name of the shared cross-cluster WAN link


@dataclass
class StepObservation:
    step: int
    step_time: float                          # measured wall time (s)
    stage_times: Optional[List[float]] = None  # per-stage f+b per microbatch


class TelemetryCalibrator:
    def __init__(self, alpha: float = 0.25, deadband: float = 0.05,
                 min_efficiency: float = 0.05):
        self.alpha = alpha
        self.deadband = deadband
        self.min_efficiency = min_efficiency
        self._eff: Dict[str, float] = {}       # sub-cluster name -> EWMA estimate
        self._bw: Dict[str, float] = {}        # CROSS | sub-cluster name ->
                                               # EWMA bytes/s (inter-node tier)
        self.n_observations = 0

    # -- folding measurements ------------------------------------------------

    def _fold(self, name: str, current_eff: float, est: float):
        est = max(self.min_efficiency, est)
        prev = self._eff.get(name, current_eff)
        self._eff[name] = (1 - self.alpha) * prev + self.alpha * est

    def seed_from_kbench(self, cluster: HeteroCluster,
                         kbench) -> Dict[str, float]:
        """Seed the EWMA efficiency anchors from a measured kernel table.

        The first ``observe()`` normally anchors each sub-cluster at its
        modeled efficiency (effectively 1.0 on an uncalibrated fleet); with
        a :class:`repro.kbench.bridge.KBenchModel` (or ``KBenchConfig``)
        covering a device, the anchor becomes the *implied* efficiency —
        measured achieved MFU over the analytic ``base_mfu`` — so the first
        EWMA fold starts from measurement instead of optimism.  Uncovered
        sub-clusters and already-seeded names are left alone.  Returns the
        seeds applied.

        Intended for fleets whose plan was priced *analytically*: when the
        plan itself already used kbench pricing, predicted stage times
        include the measured anchor and seeding here would double-count the
        same correction."""
        from repro.kbench.bridge import KBenchConfig, KBenchModel

        if isinstance(kbench, KBenchConfig):
            kbench = KBenchModel(kbench)
        seeded: Dict[str, float] = {}
        for sub in cluster.subclusters:
            if sub.name in self._eff:
                continue
            measured = kbench.measured_mfu(sub)
            if measured is None:
                continue
            est = max(self.min_efficiency, measured / sub.device.base_mfu)
            self._eff[sub.name] = est
            seeded[sub.name] = est
        return seeded

    def observe(self, cluster: HeteroCluster, strategy: ParallelStrategy,
                obs: StepObservation):
        """Fold one step's measurement.  ``cluster`` must be the cluster the
        strategy was PLANNED on — its efficiencies are what the predictions
        assume, so they anchor the estimate (anchoring to an
        already-calibrated value would compound the correction).  With
        per-stage times, each stage calibrates its own sub-cluster; with only
        the aggregate step time, the global predicted/measured ratio is
        attributed to every sub-cluster the strategy runs on (coarse but
        unbiased)."""
        self.n_observations += 1
        if obs.stage_times:
            for s, t_meas in zip(strategy.stages, obs.stage_times):
                if t_meas <= 0 or s.t <= 0:
                    continue
                sub = cluster.subclusters[s.cluster_idx]
                self._fold(sub.name, sub.device.efficiency,
                           sub.device.efficiency * s.t / t_meas)
        elif obs.step_time > 0 and strategy.est_step_time > 0:
            ratio = strategy.est_step_time / obs.step_time
            for name in {cluster.subclusters[s.cluster_idx].name
                         for s in strategy.stages}:
                i = subcluster_index(cluster, name)
                eff = cluster.subclusters[i].device.efficiency
                self._fold(name, eff, eff * ratio)

    def observe_comm(self, cluster: HeteroCluster, link: str,
                     predicted_s: float, measured_s: float):
        """Fold one measured transfer/collective against its prediction for
        a bandwidth tier: ``link`` is :data:`CROSS` (the WAN) or a
        sub-cluster name (its inter-node fabric).  ``cluster`` must be the
        fleet the prediction was priced on — its bandwidth anchors the
        estimate, exactly like efficiency calibration."""
        if predicted_s <= 0 or measured_s <= 0:
            return
        self.n_observations += 1
        if link == CROSS:
            assumed = cluster.cross_bw
        else:
            assumed = cluster.subclusters[
                subcluster_index(cluster, link)].inter_node_bw
        est = max(1.0, assumed * predicted_s / measured_s)
        prev = self._bw.get(link, assumed)
        self._bw[link] = (1 - self.alpha) * prev + self.alpha * est

    # -- reading the calibration --------------------------------------------

    def efficiency(self, name: str, default: float = 1.0) -> float:
        return self._eff.get(name, default)

    def bandwidth(self, link: str, default: float = 0.0) -> float:
        """Calibrated bytes/s estimate for a tier (see :meth:`observe_comm`)."""
        return self._bw.get(link, default)

    def _bw_current(self, cluster: HeteroCluster, link: str
                    ) -> Optional[float]:
        if link == CROSS:
            return cluster.cross_bw
        try:
            return cluster.subclusters[
                subcluster_index(cluster, link)].inter_node_bw
        except KeyError:
            return None        # the sub-cluster left the fleet

    def drift(self, cluster: HeteroCluster) -> float:
        """Largest relative gap between the fleet's modeled parameters
        (per-sub-cluster efficiency, per-tier bandwidth) and the calibrated
        estimates.  The controller replans when this exceeds its
        threshold."""
        worst = 0.0
        for s in cluster.subclusters:
            if s.name not in self._eff:
                continue
            cur = s.device.efficiency
            worst = max(worst, abs(self._eff[s.name] - cur) / max(cur, 1e-9))
        for link, est in self._bw.items():
            cur = self._bw_current(cluster, link)
            if cur is not None:
                worst = max(worst, abs(est - cur) / max(cur, 1e-9))
        return worst

    def calibrated(self, cluster: HeteroCluster) -> HeteroCluster:
        """Cluster value with estimates applied (deadband-gated per
        sub-cluster: small drifts keep the modeled value so equal-fingerprint
        plan-cache hits survive noise)."""
        out = cluster
        for s in cluster.subclusters:
            est = self._eff.get(s.name)
            if est is None:
                continue
            cur = s.device.efficiency
            if abs(est - cur) / max(cur, 1e-9) > self.deadband:
                out = set_efficiency(out, s.name, est)
        for link, est in self._bw.items():
            cur = self._bw_current(out, link)
            if cur is None:
                continue
            if abs(est - cur) / max(cur, 1e-9) > self.deadband:
                out = with_cross_bw(out, est) if link == CROSS \
                    else set_inter_node_bw(out, link, est)
        return out

    def reset(self, name: Optional[str] = None):
        """Forget estimates (e.g. after hardware replacement)."""
        if name is None:
            self._eff.clear()
            self._bw.clear()
        else:
            self._eff.pop(name, None)
            self._bw.pop(name, None)

    def reset_bandwidth(self, link: Optional[str] = None):
        """Forget bandwidth estimates only (a committed bandwidth change
        supersedes the EWMA history for that tier)."""
        if link is None:
            self._bw.clear()
        else:
            self._bw.pop(link, None)
