"""Online calibration: measured step/stage times -> EWMA efficiency factors.

The planner's cost model predicts per-stage times from ``DeviceProfile``
specs scaled by ``device.efficiency``.  On a live fleet the prediction
drifts — thermal throttling, noisy neighbors, background daemons.  The
calibrator folds measurements back into per-sub-cluster efficiency estimates:

    eff_est = eff_used_at_plan_time * t_predicted / t_measured

EWMA-smoothed per sub-cluster.  ``calibrated(cluster)`` returns a cluster
value with the estimates applied (only when outside the deadband, so noise
does not thrash the plan cache), and ``drift(cluster)`` is the controller's
replan trigger signal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import HeteroCluster, set_efficiency, subcluster_index
from repro.core.strategy import ParallelStrategy


@dataclass
class StepObservation:
    step: int
    step_time: float                          # measured wall time (s)
    stage_times: Optional[List[float]] = None  # per-stage f+b per microbatch


class TelemetryCalibrator:
    def __init__(self, alpha: float = 0.25, deadband: float = 0.05,
                 min_efficiency: float = 0.05):
        self.alpha = alpha
        self.deadband = deadband
        self.min_efficiency = min_efficiency
        self._eff: Dict[str, float] = {}       # sub-cluster name -> EWMA estimate
        self.n_observations = 0

    # -- folding measurements ------------------------------------------------

    def _fold(self, name: str, current_eff: float, est: float):
        est = max(self.min_efficiency, est)
        prev = self._eff.get(name, current_eff)
        self._eff[name] = (1 - self.alpha) * prev + self.alpha * est

    def observe(self, cluster: HeteroCluster, strategy: ParallelStrategy,
                obs: StepObservation):
        """Fold one step's measurement.  ``cluster`` must be the cluster the
        strategy was PLANNED on — its efficiencies are what the predictions
        assume, so they anchor the estimate (anchoring to an
        already-calibrated value would compound the correction).  With
        per-stage times, each stage calibrates its own sub-cluster; with only
        the aggregate step time, the global predicted/measured ratio is
        attributed to every sub-cluster the strategy runs on (coarse but
        unbiased)."""
        self.n_observations += 1
        if obs.stage_times:
            for s, t_meas in zip(strategy.stages, obs.stage_times):
                if t_meas <= 0 or s.t <= 0:
                    continue
                sub = cluster.subclusters[s.cluster_idx]
                self._fold(sub.name, sub.device.efficiency,
                           sub.device.efficiency * s.t / t_meas)
        elif obs.step_time > 0 and strategy.est_step_time > 0:
            ratio = strategy.est_step_time / obs.step_time
            for name in {cluster.subclusters[s.cluster_idx].name
                         for s in strategy.stages}:
                i = subcluster_index(cluster, name)
                eff = cluster.subclusters[i].device.efficiency
                self._fold(name, eff, eff * ratio)

    # -- reading the calibration --------------------------------------------

    def efficiency(self, name: str, default: float = 1.0) -> float:
        return self._eff.get(name, default)

    def drift(self, cluster: HeteroCluster) -> float:
        """Largest relative gap between a sub-cluster's modeled efficiency
        and the calibrated estimate.  The controller replans when this
        exceeds its threshold."""
        worst = 0.0
        for s in cluster.subclusters:
            if s.name not in self._eff:
                continue
            cur = s.device.efficiency
            worst = max(worst, abs(self._eff[s.name] - cur) / max(cur, 1e-9))
        return worst

    def calibrated(self, cluster: HeteroCluster) -> HeteroCluster:
        """Cluster value with estimates applied (deadband-gated per
        sub-cluster: small drifts keep the modeled value so equal-fingerprint
        plan-cache hits survive noise)."""
        out = cluster
        for s in cluster.subclusters:
            est = self._eff.get(s.name)
            if est is None:
                continue
            cur = s.device.efficiency
            if abs(est - cur) / max(cur, 1e-9) > self.deadband:
                out = set_efficiency(out, s.name, est)
        return out

    def reset(self, name: Optional[str] = None):
        """Forget estimates (e.g. after hardware replacement)."""
        if name is None:
            self._eff.clear()
        else:
            self._eff.pop(name, None)
