"""ElasticController: the offline HAPT planner closed into a runtime loop.

Owns the current ``HeteroCluster`` + ``ParallelStrategy``, consumes cluster
events, and picks the *cheapest sufficient* response:

1. **warm-up retune** — a bandwidth-only change leaves stage placement and
   compute untouched; recompute inter-stage comm times and the H-1F1B
   warm-up counts (§4) in place.  Near-free.
2. **incremental re-search** — the DP re-runs, warm-started from the shared
   stage-cost cache (``ZeroRedundantProfiler.cost_cache``): only meshes of
   the *changed* sub-cluster miss; untouched sub-clusters are never
   re-profiled.
3. **full replan** — cold cache (first plan, or every sub-cluster changed).

Voluntary replans (the fleet still runs the current plan) are gated by the
amortization rule:

    (t_current - t_candidate) * remaining_steps  >  migration_bytes/cross_bw
                                                    + search_time

Forced replans (the plan no longer fits the fleet) always adopt.  Adopted
plans are persisted as JSON (``ParallelStrategy.to_json``) in
``plan_cache_dir`` keyed by a fingerprint of (arch, planner config, cluster),
so a restarted controller reloads instead of re-searching.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.cluster import (
    HeteroCluster, SubCluster, cluster_fingerprint, cluster_from_dict,
    cluster_to_dict, remove_nodes,
)
from repro.core.dp_search import SearchTimeout
from repro.core.h1f1b import h1f1b_counts
from repro.core.layering import Layer, build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import eta_load_balance, sim_memo_stats, simulate
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.core.strategy import ParallelStrategy
from repro.migrate import (
    DEFAULT_RESTORE_BW, diff_layouts, layout_from_strategy, lost_devices,
    price_migration,
)
from repro.runtime.events import (
    BandwidthShift, ClusterEvent, NodeJoin, apply_event,
)
from repro.runtime.replay import (
    feasible_under, project_step, recompute_c_links,
)
from repro.runtime.telemetry import (
    CROSS, StepObservation, TelemetryCalibrator,
)

# Plan-cache entry format: {"schema": 2, "cluster": <fleet spec>,
# "strategy": <ParallelStrategy dict>}.  The cluster rider is what lets the
# degraded ladder check a cached plan's feasibility on a *different* fleet
# (feasible_under needs the fleet the plan was priced on).  Legacy raw
# strategy JSON still loads for keyed hits; anything unparseable is
# quarantined to ``*.bad`` and treated as a miss.
PLAN_CACHE_SCHEMA = 2


@dataclass
class ControllerConfig:
    """Controller knobs.  Units: steps are training-step counts, times are
    seconds, ``drift_threshold``/``replan_slowdown`` are dimensionless
    ratios."""
    total_steps: int = 10_000          # training horizon (amortization window)
    seq_len: int = 1024
    global_batch: int = 1024
    replan_slowdown: float = 1.15      # bw retune worse than this vs. pre-event
                                       # -> also evaluate a re-search
    drift_threshold: float = 0.15      # telemetry drift that triggers a replan
    telemetry_warmup_steps: int = 1    # ignore the first N measured steps
                                       # (jit compilation inflates them)
    amortize: bool = True              # False = always adopt a better plan
    plan_cache_dir: Optional[str] = None
    migration_pricing: str = "priced"  # "priced": layout differ + netsim
                                       # (repro.migrate); "legacy": the old
                                       # params-over-the-cross-link guess
    opt_bytes_per_param: float = 2.0   # optimizer bytes per param byte (ZeRO-1)
    restore_bw: float = DEFAULT_RESTORE_BW  # checkpoint-restore path, bytes/s
    overlap_migration: bool = True     # charge only wall beyond the old
                                       # plan's drain, not stop-the-world
    # -- chaos hardening (all defaults preserve the unhardened decision
    #    sequence exactly: windows of 0 never defer, and the ladder only
    #    engages where the unhardened controller raised) ------------------
    debounce_steps: int = 0            # >0: voluntary replans wait until the
                                       # fleet has been quiet this many steps
                                       # (events coalesce into one re-search)
    min_steps_between_replans: int = 0  # hysteresis: voluntary re-searches at
                                       # least this many steps apart
    replan_deadline_s: float = 0.0     # wall-clock budget per re-search;
                                       # exceeded -> SearchTimeout -> the
                                       # degraded ladder (0 = unlimited)
    degraded_ladder: bool = True       # False = legacy behavior: planner
                                       # failure on a broken plan raises
                                       # (the unhardened baseline)
    restart_retry_steps: int = 25      # while checkpoint-restarted, retry
                                       # planning every N steps even without
                                       # a fleet event


@dataclass
class ReplanDecision:
    """One controller reaction.  All times are seconds; ``step_time_*`` are
    per-training-step, ``search_time_s``/``migration_s`` are one-off
    downtime charged to the wall clock at the decision step."""
    step: int
    action: str                        # none | warmup_only | incremental |
                                       # full | deferred | ignored |
                                       # degraded_cached | degraded_pool_drop
                                       # | degraded_half_batch |
                                       # checkpoint_restart | restart
    reason: str
    event: Optional[str] = None
    step_time_before: float = 0.0      # current plan under the new conditions
    step_time_after: float = 0.0       # adopted (or retained) plan
    search_time_s: float = 0.0
    migration_s: float = 0.0
    migration_bytes: float = 0.0       # live + checkpoint-restored bytes the
                                       # adopted plan must ship (differ bound)
    plan_cache_hit: bool = False
    profile_cache_hits: int = 0
    sim_memo_hits: int = 0      # pipesim memo hits while handling this event
    sim_memo_misses: int = 0    # (hits > 0 on a warm re-plan = cache-served)
    coalesced: int = 0          # deferred events folded into this decision
    serve_replanned: bool = False  # serving placement re-searched alongside

    @property
    def downtime_s(self) -> float:
        return self.search_time_s + self.migration_s

    def describe(self) -> str:
        parts = [f"step {self.step}: {self.action} ({self.reason})"]
        if self.step_time_before and self.step_time_after:
            parts.append(f"{self.step_time_before * 1e3:.0f}ms"
                         f" -> {self.step_time_after * 1e3:.0f}ms")
        if self.downtime_s:
            parts.append(f"downtime {self.downtime_s:.2f}s")
        if self.migration_bytes:
            parts.append(f"migrate {self.migration_bytes / 1e6:.0f}MB")
        if self.sim_memo_hits or self.sim_memo_misses:
            parts.append(f"sim-cache {self.sim_memo_hits}h"
                         f"/{self.sim_memo_misses}m")
        return " ".join(parts)


class ElasticController:
    """Event -> cheapest-sufficient-replan state machine (module docstring).

    Invariants: ``self.cluster`` is always the *true* fleet and
    ``self.plan_cluster`` the fleet the adopted ``self.strategy`` was priced
    on (telemetry anchors to the latter); layering is built once and reused
    across every replan; ``profile_cache`` keys fingerprint everything the
    cost model reads — including the intra-op sharding degree, so a
    ``planner_cfg`` with ``intra_op=True`` re-searches the *joint*
    inter+intra space incrementally on cluster events (only the changed
    sub-cluster's variants miss).  All step times are seconds.
    """

    def __init__(self, cluster: HeteroCluster,
                 arch: Union[str, ArchConfig],
                 planner_cfg: Optional[PlannerConfig] = None,
                 cfg: Optional[ControllerConfig] = None,
                 telemetry: Optional[TelemetryCalibrator] = None,
                 injector=None, serving_cfg=None):
        self.cfg = cfg or ControllerConfig()
        self.planner_cfg = planner_cfg or PlannerConfig()
        self.arch = get_config(arch) if isinstance(arch, str) else arch
        self.cluster = cluster
        # layering is fleet-independent: build once, reuse across every replan
        ops = build_op_sequence(self.arch, seq_len=self.cfg.seq_len)
        self.layers: List[Layer] = build_layers(
            ops, self.planner_cfg.granularity, z=self.planner_cfg.z_heavy)
        self.profile_cache: Dict = {}       # shared stage-cost cache (tables)
        self.telemetry = telemetry or TelemetryCalibrator()
        self.strategy: Optional[ParallelStrategy] = None
        self.plan_cluster: Optional[HeteroCluster] = None
        self.decisions: List[ReplanDecision] = []
        self._mem_plans: Dict[str, str] = {}   # key -> cache-entry JSON
        self._last_observed_step: Optional[int] = None
        # chaos hardening state
        self.injector = injector            # chaos.inject.FaultInjector | None
        self.serving_cfg = serving_cfg      # serving.config value | None
        self.serve_plan = None              # last good ServePlan (follow-on)
        self.serve_replans = 0
        self._serve_cost_cache: Dict = {}
        self._removed_pools: Dict[str, SubCluster] = {}  # specs of pools that
        #                                     left the fleet (templated rejoin)
        self._bootstrapped = False
        self._pending_why: Optional[str] = None   # coalesced deferred reason
        self._pending_events = 0
        self._pending_bw_only = True
        self._last_event_step = -(1 << 30)
        self._last_search_step = -(1 << 30)
        self._last_restart_try = -(1 << 30)
        self._last_plan_error: Optional[str] = None
        # observability (record-only): a repro.obs.DriftLedger the facade
        # wires when HarpConfig.obs is set — it observes the same telemetry
        # this controller acts on but never alters a decision
        self.drift_ledger = None

    # ------------------------------------------------------------------
    # planning (with persistent plan cache + warm profile tables)
    # ------------------------------------------------------------------

    def _plan_key(self, cluster: HeteroCluster,
                  pcfg: Optional[PlannerConfig] = None,
                  global_batch: Optional[int] = None) -> str:
        pcfg = pcfg or self.planner_cfg
        pc = dataclasses.asdict(pcfg)
        # callables don't serialize; key on identity so an analytic-model plan
        # is never silently reused by an on-hardware-profiling controller
        fn = pc.pop("measure_fn", None)
        pc["measure_fn_id"] = None if fn is None else \
            getattr(fn, "__qualname__", repr(fn))
        # execution knobs don't alter plans: worker parallelism, the search
        # engine/batching (oracle and vectorized are bit-identical), and the
        # wall-clock deadline (a search that *finishes* under a deadline
        # found the same optimum an unbounded one would)
        for knob in ("n_workers", "engine", "batch_size", "deadline_s"):
            pc["search"].pop(knob, None)
        # search() overwrites its n_microbatches from the planner config at
        # plan time; normalize so keys match before and after the first plan
        pc["search"]["n_microbatches"] = pcfg.n_microbatches
        material = json.dumps({
            "arch": self.arch.arch_id,
            "seq_len": self.cfg.seq_len,
            "global_batch": global_batch or self.cfg.global_batch,
            "planner": pc,
            "cluster": cluster_fingerprint(cluster),
        }, sort_keys=True, default=str)
        return hashlib.sha1(material.encode()).hexdigest()[:16]

    def _cache_path(self, key: str) -> Optional[str]:
        if not self.cfg.plan_cache_dir:
            return None
        return os.path.join(self.cfg.plan_cache_dir, f"plan_{key}.json")

    @staticmethod
    def _parse_plan_entry(s: str) -> Optional[
            Tuple[ParallelStrategy, Optional[HeteroCluster]]]:
        """(strategy, fleet-it-was-planned-on | None) — None on corrupt or
        stale-schema entries (the caller treats those as cache misses).
        Legacy entries (raw strategy JSON, no cluster rider) still load."""
        try:
            d = json.loads(s)
            if isinstance(d, dict) and "strategy" in d:
                if d.get("schema") != PLAN_CACHE_SCHEMA:
                    return None
                return (ParallelStrategy.from_json(json.dumps(d["strategy"])),
                        cluster_from_dict(d["cluster"]))
            return ParallelStrategy.from_json(s), None
        except Exception:
            return None

    def _load_cached_plan(self, key: str) -> Optional[ParallelStrategy]:
        s = self._mem_plans.get(key)
        path = self._cache_path(key)
        if s is None:
            if not (path and os.path.exists(path)):
                return None
            with open(path) as f:
                s = f.read()
        parsed = self._parse_plan_entry(s)
        if parsed is None:
            # corrupt or stale-schema entry: quarantine so the next run
            # doesn't trip on it again, report a miss (never raise)
            self._mem_plans.pop(key, None)
            if path and os.path.exists(path):
                os.replace(path, path + ".bad")
            return None
        self._mem_plans[key] = s
        return parsed[0]

    def _store_plan(self, key: str, strategy: ParallelStrategy,
                    cluster: HeteroCluster):
        s = json.dumps({"schema": PLAN_CACHE_SCHEMA,
                        "cluster": cluster_to_dict(cluster),
                        "strategy": json.loads(strategy.to_json())})
        self._mem_plans[key] = s
        path = self._cache_path(key)
        if path:
            os.makedirs(self.cfg.plan_cache_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(s)
            os.replace(tmp, path)

    def _cached_candidates(self):
        """Every parseable cache entry that carries its fleet rider —
        the degraded ladder's rung-1 pool.  In-memory entries first, then
        any on-disk entries not already seen."""
        seen = set()
        for key, s in list(self._mem_plans.items()):
            parsed = self._parse_plan_entry(s)
            if parsed is not None and parsed[1] is not None:
                seen.add(key)
                yield parsed
        d = self.cfg.plan_cache_dir
        if d and os.path.isdir(d):
            for fn in sorted(os.listdir(d)):
                if not (fn.startswith("plan_") and fn.endswith(".json")):
                    continue
                if fn[5:-5] in seen:
                    continue
                try:
                    with open(os.path.join(d, fn)) as f:
                        s = f.read()
                except OSError:
                    continue
                parsed = self._parse_plan_entry(s)
                if parsed is not None and parsed[1] is not None:
                    yield parsed

    def _plan(self, cluster: HeteroCluster, *,
              pcfg: Optional[PlannerConfig] = None,
              global_batch: Optional[int] = None
              ) -> Tuple[Optional[ParallelStrategy], float, bool, int]:
        """(strategy | None, search_seconds, plan_cache_hit, profile_hits).
        ``self._last_plan_error`` records why a None came back ("timeout",
        "injected timeout", "injected infeasible", or the error text)."""
        self._last_plan_error = None
        key = self._plan_key(cluster, pcfg, global_batch)
        cached = self._load_cached_plan(key)
        if cached is not None:
            return cached, 0.0, True, 0
        inj = self.injector
        if inj is not None:
            fault = inj.planner_fault()
            if fault == "timeout":
                self._last_plan_error = "injected timeout"
                burned = self.cfg.replan_deadline_s \
                    if self.cfg.replan_deadline_s > 0 \
                    else inj.cfg.planner_timeout_s
                return None, burned, False, 0
            if fault == "infeasible":
                self._last_plan_error = "injected infeasible"
                return None, 0.0, False, 0
        run_cfg = pcfg or self.planner_cfg
        if self.cfg.replan_deadline_s > 0 and run_cfg.search.deadline_s <= 0:
            run_cfg = dataclasses.replace(
                run_cfg, search=dataclasses.replace(
                    run_cfg.search, deadline_s=self.cfg.replan_deadline_s))
        planner = HAPTPlanner(cluster, run_cfg)
        t0 = time.perf_counter()
        try:
            strategy = planner.plan(
                self.arch, seq_len=self.cfg.seq_len,
                global_batch=global_batch or self.cfg.global_batch,
                layers=self.layers, profile_cache=self.profile_cache)
        except (RuntimeError, AssertionError) as exc:
            self._last_plan_error = "timeout" \
                if isinstance(exc, SearchTimeout) else str(exc)
            return None, time.perf_counter() - t0, False, 0
        dt = time.perf_counter() - t0
        hits = strategy.planner_meta.get("profiler", {}).get("n_cache_hits", 0)
        self._store_plan(key, strategy, cluster)
        return strategy, dt, False, hits

    def bootstrap(self) -> ParallelStrategy:
        """Initial plan on the current fleet."""
        snap = sim_memo_stats().snapshot()
        strategy, dt, cache_hit, hits = self._plan(self.cluster)
        if strategy is None:
            raise RuntimeError("bootstrap planning failed: no feasible plan")
        self.strategy = strategy
        self.plan_cluster = self.cluster
        self._bootstrapped = True
        live = sim_memo_stats()
        self.decisions.append(ReplanDecision(
            step=0, action="incremental" if (cache_hit or hits) else "full",
            reason="bootstrap", step_time_after=strategy.est_step_time,
            search_time_s=dt, plan_cache_hit=cache_hit,
            profile_cache_hits=hits,
            sim_memo_hits=live.hits - snap.hits,
            sim_memo_misses=live.misses - snap.misses))
        return strategy

    # ------------------------------------------------------------------
    # event handling
    # ------------------------------------------------------------------

    def handle(self, event: ClusterEvent, *,
               step: Optional[int] = None) -> ReplanDecision:
        """Fold one fleet event: apply it to the true cluster, then walk the
        decision ladder (retune / incremental re-search / full replan /
        keep).  Returns the decision, also appended to ``self.decisions``.

        With ``cfg.degraded_ladder`` (the default), this never raises once
        bootstrap has succeeded: unappliable events are recorded and
        skipped, planner failures and timeouts fall down the degraded-mode
        ladder, and the committed strategy never references a removed node.
        """
        step = event.step if step is None else step
        hardened = self.cfg.degraded_ladder and self._bootstrapped
        self._last_event_step = step
        try:
            event, new_cluster = self._apply_event_tracked(event)
        except Exception as exc:
            if not hardened:
                raise
            decision = ReplanDecision(
                step=step, action="ignored",
                reason=f"unappliable event ({exc})", event=event.describe(),
                step_time_after=self.strategy.est_step_time
                if self.strategy else 0.0)
            self.decisions.append(decision)
            return decision
        if self._bootstrapped and self.strategy is None:
            # checkpoint-restart state: every fleet event is a chance to
            # come back up
            return self._attempt_restart(new_cluster, step, event.describe())
        bandwidth_only = isinstance(event, BandwidthShift)
        if not hardened:
            return self._react(new_cluster, step, event.describe(),
                               bandwidth_only=bandwidth_only)
        return self._guarded_react(new_cluster, step, event.describe(),
                                   bandwidth_only)

    def _apply_event_tracked(
            self, event: ClusterEvent
    ) -> Tuple[ClusterEvent, HeteroCluster]:
        """``apply_event`` plus pool-spec memory: remembers the spec of every
        pool that leaves the fleet so a template-less rejoin targeting a
        vanished pool can re-create it."""
        if isinstance(event, NodeJoin) and event.template is None:
            names = {s.name for s in self.cluster.subclusters}
            if event.subcluster not in names \
                    and event.subcluster in self._removed_pools:
                event = dataclasses.replace(
                    event, template=self._removed_pools[event.subcluster])
        before = {s.name: s for s in self.cluster.subclusters}
        new_cluster = apply_event(self.cluster, event)
        after = {s.name for s in new_cluster.subclusters}
        for name, sub in before.items():
            if name not in after:
                self._removed_pools[name] = sub
        for name in after:
            self._removed_pools.pop(name, None)
        return event, new_cluster

    def poll(self, step: int) -> Optional[ReplanDecision]:
        """Per-step tick (the replay harness calls this every step): fires
        a deferred (debounced) re-search once both windows close, and
        retries planning while checkpoint-restarted.  None = nothing due."""
        if self._bootstrapped and self.strategy is None:
            if step - self._last_restart_try >= max(
                    1, self.cfg.restart_retry_steps):
                return self._attempt_restart(self.cluster, step,
                                             "restart retry")
            return None
        if self._pending_events == 0 or self.strategy is None:
            return None
        c = self.cfg
        if c.debounce_steps > 0 \
                and step - self._last_event_step < c.debounce_steps:
            return None
        if c.min_steps_between_replans > 0 \
                and step - self._last_search_step < c.min_steps_between_replans:
            return None
        why = f"deferred x{self._pending_events}: {self._pending_why}"
        n, bw_only = self._pending_events, self._pending_bw_only
        self._pending_why, self._pending_events = None, 0
        self._pending_bw_only = True
        decision = self._guarded_react(self.cluster, step, why, bw_only)
        decision.coalesced = n
        return decision

    def on_step_time(self, step: int, step_time: float,
                     stage_times: Optional[Sequence[float]] = None
                     ) -> Optional[ReplanDecision]:
        """Trainer telemetry hook: fold the measured step time; replan when
        the calibrated fleet drifts past the threshold."""
        if self.strategy is None:
            return None
        if step <= self.cfg.telemetry_warmup_steps:
            return None    # jit-compile-inflated steps would poison the EWMA
        # anchor the calibration to the efficiencies the prediction was made
        # with (plan_cluster), not the current fleet value — anchoring to the
        # already-calibrated value would compound the correction every step
        self.telemetry.observe(
            self.plan_cluster, self.strategy,
            StepObservation(step, step_time,
                            list(stage_times) if stage_times else None))
        if self.drift_ledger is not None:
            self.drift_ledger.observe_step(step, step_time, stage_times)
        self._last_observed_step = step
        drift = self.telemetry.drift(self.cluster)
        if drift <= self.cfg.drift_threshold:
            return None
        calibrated = self.telemetry.calibrated(self.cluster)
        return self._react(calibrated, step,
                           f"telemetry drift {drift:.0%}", bandwidth_only=False)

    def on_comm_time(self, step: int, link: str, predicted_s: float,
                     measured_s: float) -> Optional[ReplanDecision]:
        """Comm telemetry hook: fold one measured transfer/collective time
        against its prediction for a bandwidth tier (``"cross"`` or a
        sub-cluster name — see ``telemetry.observe_comm``).  When the
        calibrated fleet drifts past the threshold the decision ladder runs
        as a bandwidth-only change; a re-search then rebuilds the
        ``CommModel`` from the calibrated tiers, so collective algorithms
        are *re-selected* under the observed bandwidths (a congested WAN
        tips ring syncs into the two-level hierarchy, and vice versa)."""
        if self.strategy is None:
            return None
        self.telemetry.observe_comm(self.plan_cluster, link,
                                    predicted_s, measured_s)
        drift = self.telemetry.drift(self.cluster)
        if drift <= self.cfg.drift_threshold:
            return None
        calibrated = self.telemetry.calibrated(self.cluster)
        if cluster_fingerprint(calibrated) == cluster_fingerprint(self.cluster):
            return None
        return self._react(calibrated, step,
                           f"comm drift on {link} ({drift:.0%})",
                           bandwidth_only=True)

    def on_straggler(self, step: int, step_time: float, ewma: float
                     ) -> Optional[ReplanDecision]:
        """Drop-in for ``Trainer(on_straggler=...)`` — a sustained skew is a
        strong single observation; fold it (unless on_step_time already saw
        this step: the Trainer fires both hooks with the same measurement)
        and react immediately."""
        if self.strategy is None:
            return None
        if self._last_observed_step != step:
            self.telemetry.observe(self.plan_cluster, self.strategy,
                                   StepObservation(step, step_time))
            self._last_observed_step = step
        calibrated = self.telemetry.calibrated(self.cluster)
        if cluster_fingerprint(calibrated) == cluster_fingerprint(self.cluster):
            return None
        return self._react(calibrated, step,
                           f"straggler {step_time / max(ewma, 1e-12):.2f}x",
                           bandwidth_only=False)

    def trainer_hooks(self) -> Dict:
        """Keyword arguments for ``train.trainer.Trainer``."""
        return {"on_straggler": self.on_straggler,
                "on_step_time": self.on_step_time}

    # ------------------------------------------------------------------
    # hardened path: debounce + never-raise + degraded ladder
    # ------------------------------------------------------------------

    def _windows_open(self, step: int) -> bool:
        """True while a voluntary re-search should wait: the fleet hasn't
        been quiet for ``debounce_steps``, or the last search was fewer than
        ``min_steps_between_replans`` steps ago."""
        c = self.cfg
        if c.debounce_steps > 0 \
                and step - self._last_event_step < c.debounce_steps:
            return True
        if c.min_steps_between_replans > 0 \
                and step - self._last_search_step < c.min_steps_between_replans:
            return True
        return False

    def _guarded_react(self, new_cluster: HeteroCluster, step: int, why: str,
                       bandwidth_only: bool) -> ReplanDecision:
        """The hardened wrapper around :meth:`_react`: voluntary replans
        within the debounce/hysteresis windows are deferred (coalesced into
        one later re-search — a flapping node costs one replan, not one per
        flap), and *any* failure of the planning path falls down the
        degraded ladder instead of raising."""
        try:
            feasible = feasible_under(self.strategy, self.plan_cluster,
                                      new_cluster)
            if feasible and self._windows_open(step):
                # the fleet still fits the committed plan: absorb the event
                # now (bandwidth retunes are near-free), search later
                if bandwidth_only:
                    self._retune_schedule(new_cluster)
                self._pending_events += 1
                self._pending_bw_only = self._pending_bw_only and bandwidth_only
                self._pending_why = why if self._pending_why is None \
                    else f"{self._pending_why} + {why}"
                decision = ReplanDecision(
                    step=step, action="deferred",
                    reason=(f"{why}; within replan window "
                            f"({self._pending_events} pending)"),
                    event=why,
                    step_time_after=self.strategy.est_step_time)
                return self._commit(decision, new_cluster, adopted=None)
            return self._react(new_cluster, step, why,
                               bandwidth_only=bandwidth_only)
        except Exception as exc:
            return self._ladder(
                new_cluster, step,
                f"{why}; planning failed ({type(exc).__name__}: {exc})")

    def _degraded_candidate(self, new_cluster: HeteroCluster):
        """Rungs 1-3 of the degraded ladder.  Returns
        ``(strategy, plan_cluster, action, note)`` or None; never raises
        past what the caller's guard absorbs."""
        # rung 1: best cached plan that still fits a surviving subset
        best = None
        for strat, cached_cl in self._cached_candidates():
            if not feasible_under(strat, cached_cl, new_cluster):
                continue
            res = project_step(strat, cached_cl, new_cluster, self.layers)
            if res is None:
                continue
            if best is None or res.makespan < best[2]:
                best = (strat, cached_cl, res.makespan)
        if best is not None:
            return (best[0], best[1], "degraded_cached",
                    f"cached plan projected at {best[2] * 1e3:.0f}ms/step")
        # rung 2: drop the smallest pool(s) and re-search — a partially-dead
        # or unplannable pool shouldn't take the fleet down with it
        fleet = new_cluster
        while len(fleet.subclusters) > 1:
            smallest = min(fleet.subclusters, key=lambda s: s.peak_flops)
            fleet = remove_nodes(fleet, smallest.name, smallest.n_nodes)
            cand, _, _, _ = self._plan(fleet)
            if cand is not None:
                return (cand, fleet, "degraded_pool_drop",
                        f"re-searched without pool {smallest.name!r}")
        # rung 3: halve the microbatch count (and the global batch with it,
        # so per-microbatch memory is unchanged) until something fits
        B = self.planner_cfg.n_microbatches // 2
        gb = self.cfg.global_batch // 2
        while B >= 1 and gb >= 1:
            pcfg = dataclasses.replace(self.planner_cfg, n_microbatches=B)
            cand, _, _, _ = self._plan(new_cluster, pcfg=pcfg,
                                       global_batch=gb)
            if cand is not None:
                return (cand, new_cluster, "degraded_half_batch",
                        f"halved to B={B}, global batch {gb}")
            B //= 2
            gb //= 2
        return None

    def _ladder(self, new_cluster: HeteroCluster, step: int,
                why: str, charged: float = 0.0) -> ReplanDecision:
        """Guaranteed degraded-mode response when planning failed or timed
        out: cached feasible plan -> drop smallest pool -> halve microbatch
        count -> checkpoint-restart.  Never raises; always leaves the
        controller in a state where the committed strategy (if any) fits
        ``new_cluster``."""
        t0 = time.perf_counter()
        try:
            found = self._degraded_candidate(new_cluster)
            if found is not None:
                strat, pcl, action, note = found
                decision = ReplanDecision(
                    step=step, action=action, reason=f"{why}; {note}",
                    event=why, step_time_after=strat.est_step_time,
                    search_time_s=charged + time.perf_counter() - t0)
                return self._commit(decision, new_cluster, adopted=strat,
                                    plan_cluster=pcl)
        except Exception as exc:   # the ladder itself must never raise
            why = f"{why}; ladder error ({type(exc).__name__}: {exc})"
        # rung 4: checkpoint-restart — stop earning tokens, hold position,
        # retry planning on every event (and every restart_retry_steps)
        self.strategy = None
        self.plan_cluster = None
        self.cluster = new_cluster
        self._pending_why, self._pending_events = None, 0
        self._pending_bw_only = True
        self._last_restart_try = step
        decision = ReplanDecision(
            step=step, action="checkpoint_restart",
            reason=f"{why}; no degraded plan found, holding at checkpoint",
            event=why, search_time_s=charged + time.perf_counter() - t0)
        self.decisions.append(decision)
        return decision

    def _attempt_restart(self, new_cluster: HeteroCluster, step: int,
                         why: str) -> ReplanDecision:
        """From the checkpoint-restart rung: try to come back up on the
        current fleet (full search first, then the cheap ladder rungs).
        Adoption charges the checkpoint-restore time."""
        self._last_restart_try = step
        t0 = time.perf_counter()
        pcl = new_cluster
        try:
            cand = self._plan(new_cluster)[0]
            if cand is None:
                found = self._degraded_candidate(new_cluster)
                if found is not None:
                    cand, pcl, _, note = found
                    why = f"{why}; {note}"
        except Exception:
            cand = None
        if cand is None:
            decision = ReplanDecision(
                step=step, action="none",
                reason=f"{why}; still no feasible plan "
                       "(checkpoint-restart pending)",
                event=why, search_time_s=time.perf_counter() - t0)
            self.cluster = new_cluster
            self.decisions.append(decision)
            return decision
        decision = ReplanDecision(
            step=step, action="restart",
            reason=f"{why}; restored from checkpoint", event=why,
            step_time_after=cand.est_step_time,
            search_time_s=time.perf_counter() - t0,
            migration_s=self._restore_seconds(),
            migration_bytes=self._state_bytes())
        return self._commit(decision, new_cluster, adopted=cand,
                            plan_cluster=pcl)

    def _state_bytes(self) -> float:
        return sum(l.param_bytes for l in self.layers) \
            * (1.0 + self.cfg.opt_bytes_per_param)

    def _restore_seconds(self) -> float:
        return self._state_bytes() / max(self.cfg.restore_bw, 1.0)

    # ------------------------------------------------------------------
    # decision ladder
    # ------------------------------------------------------------------

    def _react(self, new_cluster: HeteroCluster, step: int, why: str,
               bandwidth_only: bool) -> ReplanDecision:
        assert self.strategy is not None, "call bootstrap() first"
        self._memo_snap = sim_memo_stats().snapshot()
        old_est = self.strategy.est_step_time
        res = project_step(self.strategy, self.plan_cluster, new_cluster,
                           self.layers)
        feasible = res is not None
        t_before = res.makespan if feasible else float("inf")

        # rung 1: bandwidth-only -> retune comm times + warm-up counts in place
        if bandwidth_only and feasible:
            self._retune_schedule(new_cluster)
            t_retuned = self.strategy.est_step_time
            if t_retuned <= self.cfg.replan_slowdown * old_est:
                decision = ReplanDecision(
                    step=step, action="warmup_only", reason=why, event=why,
                    step_time_before=t_before, step_time_after=t_retuned)
                return self._commit(decision, new_cluster, adopted=None)
            t_before = t_retuned   # degradation too large: try a re-search

        # rung 2/3: re-search (incremental thanks to the warm profile cache)
        cand, search_s, plan_hit, profile_hits = self._plan(new_cluster)
        self._last_search_step = step
        if cand is None:
            if not feasible:
                if self.cfg.degraded_ladder and self._bootstrapped:
                    return self._ladder(
                        new_cluster, step,
                        f"{why}; plan broken and re-search found nothing "
                        f"({self._last_plan_error})", charged=search_s)
                raise RuntimeError(
                    f"fleet change ({why}) broke the plan and re-planning "
                    f"found no feasible strategy on {new_cluster.describe()}")
            decision = ReplanDecision(
                step=step, action="warmup_only" if bandwidth_only else "none",
                reason=f"{why}; re-search infeasible, keeping current plan",
                event=why, step_time_before=t_before, step_time_after=t_before,
                search_time_s=search_s)
            return self._commit(decision, new_cluster, adopted=None)

        action = "incremental" if (plan_hit or profile_hits > 0) else "full"
        mig_s, mig_bytes = self._migration_cost(cand, new_cluster)

        if not feasible:
            decision = ReplanDecision(
                step=step, action=action, reason=f"{why}; forced (plan broken)",
                event=why, step_time_before=t_before,
                step_time_after=cand.est_step_time, search_time_s=search_s,
                migration_s=mig_s, migration_bytes=mig_bytes,
                plan_cache_hit=plan_hit, profile_cache_hits=profile_hits)
            return self._commit(decision, new_cluster, adopted=cand)

        # amortization: expected gain over the remaining horizon vs. the
        # one-off cost of migrating state and having searched
        remaining = max(0, self.cfg.total_steps - step)
        gain_s = (t_before - cand.est_step_time) * remaining
        cost_s = mig_s + search_s
        if self.cfg.amortize and gain_s <= cost_s:
            decision = ReplanDecision(
                step=step, action="warmup_only" if bandwidth_only else "none",
                reason=(f"{why}; not amortized "
                        f"(gain {gain_s:.1f}s <= cost {cost_s:.1f}s)"),
                event=why, step_time_before=t_before, step_time_after=t_before,
                search_time_s=search_s, plan_cache_hit=plan_hit,
                profile_cache_hits=profile_hits)
            return self._commit(decision, new_cluster, adopted=None)

        decision = ReplanDecision(
            step=step, action=action,
            reason=f"{why}; amortized (gain {gain_s:.1f}s > cost {cost_s:.1f}s)"
            if self.cfg.amortize else f"{why}; amortization off",
            event=why, step_time_before=t_before,
            step_time_after=cand.est_step_time, search_time_s=search_s,
            migration_s=mig_s, migration_bytes=mig_bytes,
            plan_cache_hit=plan_hit, profile_cache_hits=profile_hits)
        return self._commit(decision, new_cluster, adopted=cand)

    def _commit(self, decision: ReplanDecision, new_cluster: HeteroCluster,
                adopted: Optional[ParallelStrategy],
                plan_cluster: Optional[HeteroCluster] = None
                ) -> ReplanDecision:
        """Adopt ``new_cluster`` (and ``adopted``, if any) and record the
        decision.  ``plan_cluster`` overrides the fleet the adopted strategy
        was priced on (degraded-ladder adoptions: a cached plan keeps the
        fleet it was searched on; a pool-drop plan keeps the reduced
        fleet)."""
        if adopted is not None:
            priced_on = plan_cluster if plan_cluster is not None \
                else new_cluster
            if not feasible_under(adopted, priced_on, new_cluster):
                # the no-dead-nodes invariant: nothing referencing a removed
                # node may be committed.  Unreachable by construction; the
                # hardened path catches this and checkpoint-restarts.
                raise AssertionError(
                    "refusing to commit a strategy that does not fit "
                    f"{new_cluster.describe()}")
        # pipesim-memo traffic while this decision was being made: a warm
        # re-plan whose simulations were all cache-served shows hits with
        # zero misses in the decision log (and replay traces)
        snap = getattr(self, "_memo_snap", None)
        if snap is not None:
            live = sim_memo_stats()
            decision.sim_memo_hits = live.hits - snap.hits
            decision.sim_memo_misses = live.misses - snap.misses
            self._memo_snap = None
        # a committed efficiency change (event or calibration) supersedes the
        # EWMA history for that sub-cluster — keeping the stale estimate would
        # read as spurious drift against the new model and churn replans
        old_eff = {s.name: s.device.efficiency for s in self.cluster.subclusters}
        for s in new_cluster.subclusters:
            if s.name in old_eff and old_eff[s.name] != s.device.efficiency:
                self.telemetry.reset(s.name)
        # same rule for bandwidth tiers (comm calibration)
        if new_cluster.cross_bw != self.cluster.cross_bw:
            self.telemetry.reset_bandwidth(CROSS)
        old_ib = {s.name: s.inter_node_bw for s in self.cluster.subclusters}
        for s in new_cluster.subclusters:
            if s.name in old_ib and old_ib[s.name] != s.inter_node_bw:
                self.telemetry.reset_bandwidth(s.name)
        pools_changed = (
            {(s.name, s.n_nodes, s.devices_per_node)
             for s in self.cluster.subclusters}
            != {(s.name, s.n_nodes, s.devices_per_node)
                for s in new_cluster.subclusters})
        self.cluster = new_cluster
        if adopted is not None:
            self.strategy = adopted
            self.plan_cluster = plan_cluster if plan_cluster is not None \
                else new_cluster
            if self.drift_ledger is not None:
                # the adopted strategy's estimate is the new prediction to
                # hold to account; old-plan samples don't indict it
                self.drift_ledger.register_plan(
                    {"makespan_s": adopted.est_step_time},
                    stage_pools={
                        i: self.plan_cluster.subclusters[st.cluster_idx].name
                        for i, st in enumerate(adopted.stages)})
        if pools_changed:
            self._replan_serving(new_cluster, decision)
        self.decisions.append(decision)
        return decision

    def _replan_serving(self, new_cluster: HeteroCluster,
                        decision: ReplanDecision) -> None:
        """Serving follow-on: a pool-structure change re-runs the serving
        placement search on the surviving fleet (PR 6's named remainder),
        through the same never-raise guard as training replans — a failed
        re-placement keeps the last good serve plan.  Control-plane work:
        not charged to training downtime."""
        if self.serving_cfg is None:
            return
        try:
            from repro.serving.placement import search_placement
            self.serve_plan = search_placement(
                self.arch, new_cluster, self.serving_cfg,
                cost_cache=self._serve_cost_cache)
            self.serve_replans += 1
            decision.serve_replanned = True
        except Exception as exc:
            decision.reason += (f"; serving re-placement failed "
                                f"({type(exc).__name__}), keeping last "
                                f"serve plan")

    # ------------------------------------------------------------------
    # cheap responses + costs
    # ------------------------------------------------------------------

    def _retune_schedule(self, new_cluster: HeteroCluster):
        """Bandwidth-only response: stage placement and compute stand; only
        comm times, H-1F1B warm-up counts, and the simulated step time move."""
        strat = self.strategy
        c_links = recompute_c_links(strat, self.plan_cluster, new_cluster,
                                    self.layers)
        counts = h1f1b_counts([s.t for s in strat.stages], c_links,
                              strat.n_microbatches)
        res = simulate([s.t_f for s in strat.stages],
                       [s.t_b for s in strat.stages],
                       c_links, strat.n_microbatches, counts)
        strat.c_links = c_links
        strat.warmup_counts = counts
        strat.est_step_time = res.makespan
        strat.eta = eta_load_balance(
            res.stage_compute,
            [s.n_devices
             * self.plan_cluster.subclusters[s.cluster_idx].device.peak_flops
             for s in strat.stages])
        # deliberately NOT stored in the plan cache: only genuinely searched
        # plans belong there — caching the retuned plan under the new fleet's
        # key would short-circuit rung 2's re-search with our own retune

    def _migration_cost(self, cand: ParallelStrategy,
                        new_cluster: HeteroCluster) -> Tuple[float, float]:
        """(seconds, bytes) of moving live state from the current plan to
        ``cand``.  The priced path diffs the two plans' exact per-device
        byte layouts (``repro.migrate``) — only *moved* bytes, sourced from
        the nearest surviving replica or the checkpoint — and prices the
        transfer set through the comm topology's tiered links, overlapped
        with the old plan's drain.  Bytes = live + checkpoint-restored
        (the differ's bound an executor cannot beat)."""
        if self.cfg.migration_pricing == "legacy":
            return self._migration_seconds(cand, new_cluster), 0.0
        old_lay = layout_from_strategy(
            self.strategy, self.plan_cluster, self.layers,
            opt_bytes_per_param=self.cfg.opt_bytes_per_param)
        new_lay = layout_from_strategy(
            cand, new_cluster, self.layers,
            opt_bytes_per_param=self.cfg.opt_bytes_per_param)
        lost = lost_devices(self.plan_cluster, new_cluster)
        mplan = diff_layouts(old_lay, new_lay, lost=lost)
        cost = price_migration(
            mplan, old_lay, new_cluster,
            old_strategy=self.strategy, old_cluster=self.plan_cluster,
            layers=self.layers, restore_bw=self.cfg.restore_bw,
            overlap=self.cfg.overlap_migration)
        return cost.downtime_s, float(mplan.moved_bytes + mplan.ckpt_bytes)

    def _migration_seconds(self, cand: ParallelStrategy,
                           new_cluster: HeteroCluster) -> float:
        """Legacy guess (``migration_pricing="legacy"``): parameter bytes
        whose owning sub-cluster changes, over the cross link (optimizer
        state assumed re-sharded locally, not shipped)."""
        def owners(strategy: ParallelStrategy, cluster: HeteroCluster
                   ) -> Dict[int, str]:
            out: Dict[int, str] = {}
            for s in strategy.stages:
                name = cluster.subclusters[s.cluster_idx].name
                for li in range(s.layer_start, s.layer_end):
                    out[li] = name
            return out

        old = owners(self.strategy, self.plan_cluster)
        new = owners(cand, new_cluster)
        moved = sum(self.layers[li].param_bytes
                    for li in new if old.get(li) != new[li])
        if moved <= 0:
            return 0.0
        return moved / new_cluster.cross_bw + new_cluster.cross_latency
