"""ElasticController: the offline HAPT planner closed into a runtime loop.

Owns the current ``HeteroCluster`` + ``ParallelStrategy``, consumes cluster
events, and picks the *cheapest sufficient* response:

1. **warm-up retune** — a bandwidth-only change leaves stage placement and
   compute untouched; recompute inter-stage comm times and the H-1F1B
   warm-up counts (§4) in place.  Near-free.
2. **incremental re-search** — the DP re-runs, warm-started from the shared
   stage-cost cache (``ZeroRedundantProfiler.cost_cache``): only meshes of
   the *changed* sub-cluster miss; untouched sub-clusters are never
   re-profiled.
3. **full replan** — cold cache (first plan, or every sub-cluster changed).

Voluntary replans (the fleet still runs the current plan) are gated by the
amortization rule:

    (t_current - t_candidate) * remaining_steps  >  migration_bytes/cross_bw
                                                    + search_time

Forced replans (the plan no longer fits the fleet) always adopt.  Adopted
plans are persisted as JSON (``ParallelStrategy.to_json``) in
``plan_cache_dir`` keyed by a fingerprint of (arch, planner config, cluster),
so a restarted controller reloads instead of re-searching.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster, cluster_fingerprint
from repro.core.h1f1b import h1f1b_counts
from repro.core.layering import Layer, build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import eta_load_balance, sim_memo_stats, simulate
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.core.strategy import ParallelStrategy
from repro.migrate import (
    DEFAULT_RESTORE_BW, diff_layouts, layout_from_strategy, lost_devices,
    price_migration,
)
from repro.runtime.events import BandwidthShift, ClusterEvent, apply_event
from repro.runtime.replay import project_step, recompute_c_links
from repro.runtime.telemetry import (
    CROSS, StepObservation, TelemetryCalibrator,
)


@dataclass
class ControllerConfig:
    """Controller knobs.  Units: steps are training-step counts, times are
    seconds, ``drift_threshold``/``replan_slowdown`` are dimensionless
    ratios."""
    total_steps: int = 10_000          # training horizon (amortization window)
    seq_len: int = 1024
    global_batch: int = 1024
    replan_slowdown: float = 1.15      # bw retune worse than this vs. pre-event
                                       # -> also evaluate a re-search
    drift_threshold: float = 0.15      # telemetry drift that triggers a replan
    telemetry_warmup_steps: int = 1    # ignore the first N measured steps
                                       # (jit compilation inflates them)
    amortize: bool = True              # False = always adopt a better plan
    plan_cache_dir: Optional[str] = None
    migration_pricing: str = "priced"  # "priced": layout differ + netsim
                                       # (repro.migrate); "legacy": the old
                                       # params-over-the-cross-link guess
    opt_bytes_per_param: float = 2.0   # optimizer bytes per param byte (ZeRO-1)
    restore_bw: float = DEFAULT_RESTORE_BW  # checkpoint-restore path, bytes/s
    overlap_migration: bool = True     # charge only wall beyond the old
                                       # plan's drain, not stop-the-world


@dataclass
class ReplanDecision:
    """One controller reaction.  All times are seconds; ``step_time_*`` are
    per-training-step, ``search_time_s``/``migration_s`` are one-off
    downtime charged to the wall clock at the decision step."""
    step: int
    action: str                        # none | warmup_only | incremental | full
    reason: str
    event: Optional[str] = None
    step_time_before: float = 0.0      # current plan under the new conditions
    step_time_after: float = 0.0       # adopted (or retained) plan
    search_time_s: float = 0.0
    migration_s: float = 0.0
    migration_bytes: float = 0.0       # live + checkpoint-restored bytes the
                                       # adopted plan must ship (differ bound)
    plan_cache_hit: bool = False
    profile_cache_hits: int = 0
    sim_memo_hits: int = 0      # pipesim memo hits while handling this event
    sim_memo_misses: int = 0    # (hits > 0 on a warm re-plan = cache-served)

    @property
    def downtime_s(self) -> float:
        return self.search_time_s + self.migration_s

    def describe(self) -> str:
        parts = [f"step {self.step}: {self.action} ({self.reason})"]
        if self.step_time_before and self.step_time_after:
            parts.append(f"{self.step_time_before * 1e3:.0f}ms"
                         f" -> {self.step_time_after * 1e3:.0f}ms")
        if self.downtime_s:
            parts.append(f"downtime {self.downtime_s:.2f}s")
        if self.migration_bytes:
            parts.append(f"migrate {self.migration_bytes / 1e6:.0f}MB")
        if self.sim_memo_hits or self.sim_memo_misses:
            parts.append(f"sim-cache {self.sim_memo_hits}h"
                         f"/{self.sim_memo_misses}m")
        return " ".join(parts)


class ElasticController:
    """Event -> cheapest-sufficient-replan state machine (module docstring).

    Invariants: ``self.cluster`` is always the *true* fleet and
    ``self.plan_cluster`` the fleet the adopted ``self.strategy`` was priced
    on (telemetry anchors to the latter); layering is built once and reused
    across every replan; ``profile_cache`` keys fingerprint everything the
    cost model reads — including the intra-op sharding degree, so a
    ``planner_cfg`` with ``intra_op=True`` re-searches the *joint*
    inter+intra space incrementally on cluster events (only the changed
    sub-cluster's variants miss).  All step times are seconds.
    """

    def __init__(self, cluster: HeteroCluster,
                 arch: Union[str, ArchConfig],
                 planner_cfg: Optional[PlannerConfig] = None,
                 cfg: Optional[ControllerConfig] = None,
                 telemetry: Optional[TelemetryCalibrator] = None):
        self.cfg = cfg or ControllerConfig()
        self.planner_cfg = planner_cfg or PlannerConfig()
        self.arch = get_config(arch) if isinstance(arch, str) else arch
        self.cluster = cluster
        # layering is fleet-independent: build once, reuse across every replan
        ops = build_op_sequence(self.arch, seq_len=self.cfg.seq_len)
        self.layers: List[Layer] = build_layers(
            ops, self.planner_cfg.granularity, z=self.planner_cfg.z_heavy)
        self.profile_cache: Dict = {}       # shared stage-cost cache (tables)
        self.telemetry = telemetry or TelemetryCalibrator()
        self.strategy: Optional[ParallelStrategy] = None
        self.plan_cluster: Optional[HeteroCluster] = None
        self.decisions: List[ReplanDecision] = []
        self._mem_plans: Dict[str, str] = {}   # key -> strategy JSON
        self._last_observed_step: Optional[int] = None

    # ------------------------------------------------------------------
    # planning (with persistent plan cache + warm profile tables)
    # ------------------------------------------------------------------

    def _plan_key(self, cluster: HeteroCluster) -> str:
        pc = dataclasses.asdict(self.planner_cfg)
        # callables don't serialize; key on identity so an analytic-model plan
        # is never silently reused by an on-hardware-profiling controller
        fn = pc.pop("measure_fn", None)
        pc["measure_fn_id"] = None if fn is None else \
            getattr(fn, "__qualname__", repr(fn))
        # execution knobs don't alter plans: worker parallelism, and the
        # search engine/batching (oracle and vectorized are bit-identical)
        for knob in ("n_workers", "engine", "batch_size"):
            pc["search"].pop(knob, None)
        # search() overwrites its n_microbatches from the planner config at
        # plan time; normalize so keys match before and after the first plan
        pc["search"]["n_microbatches"] = self.planner_cfg.n_microbatches
        material = json.dumps({
            "arch": self.arch.arch_id,
            "seq_len": self.cfg.seq_len,
            "global_batch": self.cfg.global_batch,
            "planner": pc,
            "cluster": cluster_fingerprint(cluster),
        }, sort_keys=True, default=str)
        return hashlib.sha1(material.encode()).hexdigest()[:16]

    def _cache_path(self, key: str) -> Optional[str]:
        if not self.cfg.plan_cache_dir:
            return None
        return os.path.join(self.cfg.plan_cache_dir, f"plan_{key}.json")

    def _load_cached_plan(self, key: str) -> Optional[ParallelStrategy]:
        if key in self._mem_plans:
            return ParallelStrategy.from_json(self._mem_plans[key])
        path = self._cache_path(key)
        if path and os.path.exists(path):
            with open(path) as f:
                s = f.read()
            self._mem_plans[key] = s
            return ParallelStrategy.from_json(s)
        return None

    def _store_plan(self, key: str, strategy: ParallelStrategy):
        s = strategy.to_json()
        self._mem_plans[key] = s
        path = self._cache_path(key)
        if path:
            os.makedirs(self.cfg.plan_cache_dir, exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(s)
            os.replace(tmp, path)

    def _plan(self, cluster: HeteroCluster
              ) -> Tuple[Optional[ParallelStrategy], float, bool, int]:
        """(strategy | None, search_seconds, plan_cache_hit, profile_hits)."""
        key = self._plan_key(cluster)
        cached = self._load_cached_plan(key)
        if cached is not None:
            return cached, 0.0, True, 0
        planner = HAPTPlanner(cluster, self.planner_cfg)
        t0 = time.perf_counter()
        try:
            strategy = planner.plan(
                self.arch, seq_len=self.cfg.seq_len,
                global_batch=self.cfg.global_batch, layers=self.layers,
                profile_cache=self.profile_cache)
        except (RuntimeError, AssertionError):
            return None, time.perf_counter() - t0, False, 0
        dt = time.perf_counter() - t0
        hits = strategy.planner_meta.get("profiler", {}).get("n_cache_hits", 0)
        self._store_plan(key, strategy)
        return strategy, dt, False, hits

    def bootstrap(self) -> ParallelStrategy:
        """Initial plan on the current fleet."""
        snap = sim_memo_stats().snapshot()
        strategy, dt, cache_hit, hits = self._plan(self.cluster)
        if strategy is None:
            raise RuntimeError("bootstrap planning failed: no feasible plan")
        self.strategy = strategy
        self.plan_cluster = self.cluster
        live = sim_memo_stats()
        self.decisions.append(ReplanDecision(
            step=0, action="incremental" if (cache_hit or hits) else "full",
            reason="bootstrap", step_time_after=strategy.est_step_time,
            search_time_s=dt, plan_cache_hit=cache_hit,
            profile_cache_hits=hits,
            sim_memo_hits=live.hits - snap.hits,
            sim_memo_misses=live.misses - snap.misses))
        return strategy

    # ------------------------------------------------------------------
    # event handling
    # ------------------------------------------------------------------

    def handle(self, event: ClusterEvent, *,
               step: Optional[int] = None) -> ReplanDecision:
        """Fold one fleet event: apply it to the true cluster, then walk the
        decision ladder (retune / incremental re-search / full replan /
        keep).  Returns the decision, also appended to ``self.decisions``."""
        step = event.step if step is None else step
        new_cluster = apply_event(self.cluster, event)
        return self._react(new_cluster, step, event.describe(),
                           bandwidth_only=isinstance(event, BandwidthShift))

    def on_step_time(self, step: int, step_time: float,
                     stage_times: Optional[Sequence[float]] = None
                     ) -> Optional[ReplanDecision]:
        """Trainer telemetry hook: fold the measured step time; replan when
        the calibrated fleet drifts past the threshold."""
        if self.strategy is None:
            return None
        if step <= self.cfg.telemetry_warmup_steps:
            return None    # jit-compile-inflated steps would poison the EWMA
        # anchor the calibration to the efficiencies the prediction was made
        # with (plan_cluster), not the current fleet value — anchoring to the
        # already-calibrated value would compound the correction every step
        self.telemetry.observe(
            self.plan_cluster, self.strategy,
            StepObservation(step, step_time,
                            list(stage_times) if stage_times else None))
        self._last_observed_step = step
        drift = self.telemetry.drift(self.cluster)
        if drift <= self.cfg.drift_threshold:
            return None
        calibrated = self.telemetry.calibrated(self.cluster)
        return self._react(calibrated, step,
                           f"telemetry drift {drift:.0%}", bandwidth_only=False)

    def on_comm_time(self, step: int, link: str, predicted_s: float,
                     measured_s: float) -> Optional[ReplanDecision]:
        """Comm telemetry hook: fold one measured transfer/collective time
        against its prediction for a bandwidth tier (``"cross"`` or a
        sub-cluster name — see ``telemetry.observe_comm``).  When the
        calibrated fleet drifts past the threshold the decision ladder runs
        as a bandwidth-only change; a re-search then rebuilds the
        ``CommModel`` from the calibrated tiers, so collective algorithms
        are *re-selected* under the observed bandwidths (a congested WAN
        tips ring syncs into the two-level hierarchy, and vice versa)."""
        if self.strategy is None:
            return None
        self.telemetry.observe_comm(self.plan_cluster, link,
                                    predicted_s, measured_s)
        drift = self.telemetry.drift(self.cluster)
        if drift <= self.cfg.drift_threshold:
            return None
        calibrated = self.telemetry.calibrated(self.cluster)
        if cluster_fingerprint(calibrated) == cluster_fingerprint(self.cluster):
            return None
        return self._react(calibrated, step,
                           f"comm drift on {link} ({drift:.0%})",
                           bandwidth_only=True)

    def on_straggler(self, step: int, step_time: float, ewma: float
                     ) -> Optional[ReplanDecision]:
        """Drop-in for ``Trainer(on_straggler=...)`` — a sustained skew is a
        strong single observation; fold it (unless on_step_time already saw
        this step: the Trainer fires both hooks with the same measurement)
        and react immediately."""
        if self.strategy is None:
            return None
        if self._last_observed_step != step:
            self.telemetry.observe(self.plan_cluster, self.strategy,
                                   StepObservation(step, step_time))
            self._last_observed_step = step
        calibrated = self.telemetry.calibrated(self.cluster)
        if cluster_fingerprint(calibrated) == cluster_fingerprint(self.cluster):
            return None
        return self._react(calibrated, step,
                           f"straggler {step_time / max(ewma, 1e-12):.2f}x",
                           bandwidth_only=False)

    def trainer_hooks(self) -> Dict:
        """Keyword arguments for ``train.trainer.Trainer``."""
        return {"on_straggler": self.on_straggler,
                "on_step_time": self.on_step_time}

    # ------------------------------------------------------------------
    # decision ladder
    # ------------------------------------------------------------------

    def _react(self, new_cluster: HeteroCluster, step: int, why: str,
               bandwidth_only: bool) -> ReplanDecision:
        assert self.strategy is not None, "call bootstrap() first"
        self._memo_snap = sim_memo_stats().snapshot()
        old_est = self.strategy.est_step_time
        res = project_step(self.strategy, self.plan_cluster, new_cluster,
                           self.layers)
        feasible = res is not None
        t_before = res.makespan if feasible else float("inf")

        # rung 1: bandwidth-only -> retune comm times + warm-up counts in place
        if bandwidth_only and feasible:
            self._retune_schedule(new_cluster)
            t_retuned = self.strategy.est_step_time
            if t_retuned <= self.cfg.replan_slowdown * old_est:
                decision = ReplanDecision(
                    step=step, action="warmup_only", reason=why, event=why,
                    step_time_before=t_before, step_time_after=t_retuned)
                return self._commit(decision, new_cluster, adopted=None)
            t_before = t_retuned   # degradation too large: try a re-search

        # rung 2/3: re-search (incremental thanks to the warm profile cache)
        cand, search_s, plan_hit, profile_hits = self._plan(new_cluster)
        if cand is None:
            if not feasible:
                raise RuntimeError(
                    f"fleet change ({why}) broke the plan and re-planning "
                    f"found no feasible strategy on {new_cluster.describe()}")
            decision = ReplanDecision(
                step=step, action="warmup_only" if bandwidth_only else "none",
                reason=f"{why}; re-search infeasible, keeping current plan",
                event=why, step_time_before=t_before, step_time_after=t_before,
                search_time_s=search_s)
            return self._commit(decision, new_cluster, adopted=None)

        action = "incremental" if (plan_hit or profile_hits > 0) else "full"
        mig_s, mig_bytes = self._migration_cost(cand, new_cluster)

        if not feasible:
            decision = ReplanDecision(
                step=step, action=action, reason=f"{why}; forced (plan broken)",
                event=why, step_time_before=t_before,
                step_time_after=cand.est_step_time, search_time_s=search_s,
                migration_s=mig_s, migration_bytes=mig_bytes,
                plan_cache_hit=plan_hit, profile_cache_hits=profile_hits)
            return self._commit(decision, new_cluster, adopted=cand)

        # amortization: expected gain over the remaining horizon vs. the
        # one-off cost of migrating state and having searched
        remaining = max(0, self.cfg.total_steps - step)
        gain_s = (t_before - cand.est_step_time) * remaining
        cost_s = mig_s + search_s
        if self.cfg.amortize and gain_s <= cost_s:
            decision = ReplanDecision(
                step=step, action="warmup_only" if bandwidth_only else "none",
                reason=(f"{why}; not amortized "
                        f"(gain {gain_s:.1f}s <= cost {cost_s:.1f}s)"),
                event=why, step_time_before=t_before, step_time_after=t_before,
                search_time_s=search_s, plan_cache_hit=plan_hit,
                profile_cache_hits=profile_hits)
            return self._commit(decision, new_cluster, adopted=None)

        decision = ReplanDecision(
            step=step, action=action,
            reason=f"{why}; amortized (gain {gain_s:.1f}s > cost {cost_s:.1f}s)"
            if self.cfg.amortize else f"{why}; amortization off",
            event=why, step_time_before=t_before,
            step_time_after=cand.est_step_time, search_time_s=search_s,
            migration_s=mig_s, migration_bytes=mig_bytes,
            plan_cache_hit=plan_hit, profile_cache_hits=profile_hits)
        return self._commit(decision, new_cluster, adopted=cand)

    def _commit(self, decision: ReplanDecision, new_cluster: HeteroCluster,
                adopted: Optional[ParallelStrategy]) -> ReplanDecision:
        # pipesim-memo traffic while this decision was being made: a warm
        # re-plan whose simulations were all cache-served shows hits with
        # zero misses in the decision log (and replay traces)
        snap = getattr(self, "_memo_snap", None)
        if snap is not None:
            live = sim_memo_stats()
            decision.sim_memo_hits = live.hits - snap.hits
            decision.sim_memo_misses = live.misses - snap.misses
            self._memo_snap = None
        # a committed efficiency change (event or calibration) supersedes the
        # EWMA history for that sub-cluster — keeping the stale estimate would
        # read as spurious drift against the new model and churn replans
        old_eff = {s.name: s.device.efficiency for s in self.cluster.subclusters}
        for s in new_cluster.subclusters:
            if s.name in old_eff and old_eff[s.name] != s.device.efficiency:
                self.telemetry.reset(s.name)
        # same rule for bandwidth tiers (comm calibration)
        if new_cluster.cross_bw != self.cluster.cross_bw:
            self.telemetry.reset_bandwidth(CROSS)
        old_ib = {s.name: s.inter_node_bw for s in self.cluster.subclusters}
        for s in new_cluster.subclusters:
            if s.name in old_ib and old_ib[s.name] != s.inter_node_bw:
                self.telemetry.reset_bandwidth(s.name)
        self.cluster = new_cluster
        if adopted is not None:
            self.strategy = adopted
            self.plan_cluster = new_cluster
        self.decisions.append(decision)
        return decision

    # ------------------------------------------------------------------
    # cheap responses + costs
    # ------------------------------------------------------------------

    def _retune_schedule(self, new_cluster: HeteroCluster):
        """Bandwidth-only response: stage placement and compute stand; only
        comm times, H-1F1B warm-up counts, and the simulated step time move."""
        strat = self.strategy
        c_links = recompute_c_links(strat, self.plan_cluster, new_cluster,
                                    self.layers)
        counts = h1f1b_counts([s.t for s in strat.stages], c_links,
                              strat.n_microbatches)
        res = simulate([s.t_f for s in strat.stages],
                       [s.t_b for s in strat.stages],
                       c_links, strat.n_microbatches, counts)
        strat.c_links = c_links
        strat.warmup_counts = counts
        strat.est_step_time = res.makespan
        strat.eta = eta_load_balance(
            res.stage_compute,
            [s.n_devices
             * self.plan_cluster.subclusters[s.cluster_idx].device.peak_flops
             for s in strat.stages])
        # deliberately NOT stored in the plan cache: only genuinely searched
        # plans belong there — caching the retuned plan under the new fleet's
        # key would short-circuit rung 2's re-search with our own retune

    def _migration_cost(self, cand: ParallelStrategy,
                        new_cluster: HeteroCluster) -> Tuple[float, float]:
        """(seconds, bytes) of moving live state from the current plan to
        ``cand``.  The priced path diffs the two plans' exact per-device
        byte layouts (``repro.migrate``) — only *moved* bytes, sourced from
        the nearest surviving replica or the checkpoint — and prices the
        transfer set through the comm topology's tiered links, overlapped
        with the old plan's drain.  Bytes = live + checkpoint-restored
        (the differ's bound an executor cannot beat)."""
        if self.cfg.migration_pricing == "legacy":
            return self._migration_seconds(cand, new_cluster), 0.0
        old_lay = layout_from_strategy(
            self.strategy, self.plan_cluster, self.layers,
            opt_bytes_per_param=self.cfg.opt_bytes_per_param)
        new_lay = layout_from_strategy(
            cand, new_cluster, self.layers,
            opt_bytes_per_param=self.cfg.opt_bytes_per_param)
        lost = lost_devices(self.plan_cluster, new_cluster)
        mplan = diff_layouts(old_lay, new_lay, lost=lost)
        cost = price_migration(
            mplan, old_lay, new_cluster,
            old_strategy=self.strategy, old_cluster=self.plan_cluster,
            layers=self.layers, restore_bw=self.cfg.restore_bw,
            overlap=self.cfg.overlap_migration)
        return cost.downtime_s, float(mplan.moved_bytes + mplan.ckpt_bytes)

    def _migration_seconds(self, cand: ParallelStrategy,
                           new_cluster: HeteroCluster) -> float:
        """Legacy guess (``migration_pricing="legacy"``): parameter bytes
        whose owning sub-cluster changes, over the cross link (optimizer
        state assumed re-sharded locally, not shipped)."""
        def owners(strategy: ParallelStrategy, cluster: HeteroCluster
                   ) -> Dict[int, str]:
            out: Dict[int, str] = {}
            for s in strategy.stages:
                name = cluster.subclusters[s.cluster_idx].name
                for li in range(s.layer_start, s.layer_end):
                    out[li] = name
            return out

        old = owners(self.strategy, self.plan_cluster)
        new = owners(cand, new_cluster)
        moved = sum(self.layers[li].param_bytes
                    for li in new if old.get(li) != new[li])
        if moved <= 0:
            return 0.0
        return moved / new_cluster.cross_bw + new_cluster.cross_latency
