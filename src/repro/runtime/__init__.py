"""Elastic runtime orchestration: the offline HAPT planner closed into an
event-driven loop (events -> telemetry -> controller -> replay).  See
DESIGN.md §4."""
from repro.runtime.controller import (
    ControllerConfig, ElasticController, ReplanDecision,
)
from repro.runtime.events import (
    BandwidthShift, ClusterEvent, EventTrace, NodeFailure, NodeJoin,
    Preemption, Straggler, apply_event, paper_trace, random_trace,
)
from repro.runtime.replay import (
    ReplayResult, ReplaySample, feasible_under, project_step, run_replay,
)
from repro.runtime.telemetry import StepObservation, TelemetryCalibrator

__all__ = [
    "ClusterEvent", "NodeFailure", "NodeJoin", "BandwidthShift", "Straggler",
    "Preemption", "EventTrace", "apply_event", "paper_trace", "random_trace",
    "TelemetryCalibrator", "StepObservation",
    "ElasticController", "ControllerConfig", "ReplanDecision",
    "run_replay", "ReplayResult", "ReplaySample", "project_step",
    "feasible_under",
]
