"""Typed cluster-event model for the elastic runtime.

Real heterogeneous fleets are dynamic: nodes fail, spot instances preempt,
cross-cluster bandwidth fluctuates, stragglers emerge.  Each condition change
is a frozen event dataclass; ``apply_event`` folds an event into the (frozen)
:class:`HeteroCluster` value via the ``core.cluster`` mutation helpers, so
fleet history is a pure left-fold over the event stream.

Traces come in two flavors: deterministic *scripted* traces (regression /
benchmark fixtures, e.g. :func:`paper_trace`) and *seeded generators*
(:func:`random_trace`) for fleet-dynamics sweeps.  Both yield an
:class:`EventTrace` — events sorted by the training step at which they strike.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.cluster import (
    GBPS, HeteroCluster, SubCluster, add_nodes, remove_nodes, set_efficiency,
    with_cross_bw,
)


@dataclass(frozen=True)
class ClusterEvent:
    """Base: something changed in the fleet at training step ``step``."""
    step: int

    def describe(self) -> str:
        return f"{type(self).__name__}@{self.step}"


@dataclass(frozen=True)
class NodeFailure(ClusterEvent):
    subcluster: str = ""
    n_nodes: int = 1

    def describe(self) -> str:
        return f"NodeFailure@{self.step}({self.subcluster} -{self.n_nodes})"


@dataclass(frozen=True)
class NodeJoin(ClusterEvent):
    """A node (re)joins — recovery after failure, or elastic scale-up.
    ``template`` re-attaches a sub-cluster that left the fleet entirely
    (its name no longer resolves): the joined nodes take its profile."""
    subcluster: str = ""
    n_nodes: int = 1
    template: Optional["SubCluster"] = None

    def describe(self) -> str:
        return f"NodeJoin@{self.step}({self.subcluster} +{self.n_nodes})"


@dataclass(frozen=True)
class BandwidthShift(ClusterEvent):
    """Cross-cluster link congestion / recovery (absolute new bytes/s)."""
    cross_bw: float = 0.0

    def describe(self) -> str:
        return f"BandwidthShift@{self.step}({self.cross_bw * 8 / 1e9:.1f} Gbps)"


@dataclass(frozen=True)
class Straggler(ClusterEvent):
    """A sub-cluster slows down: its devices' calibrated efficiency becomes
    ``efficiency`` (absolute, e.g. 0.6 = running at 60% of spec)."""
    subcluster: str = ""
    efficiency: float = 1.0

    def describe(self) -> str:
        return f"Straggler@{self.step}({self.subcluster} eff={self.efficiency:.2f})"


@dataclass(frozen=True)
class Preemption(ClusterEvent):
    """Spot-instance reclamation: like a failure, but with advance notice and
    (optionally) a scheduled return after ``duration_steps``.  ``template``
    rides along to the materialized return ``NodeJoin`` so a preemption that
    drains a sub-cluster entirely can re-create the pool from its spec."""
    subcluster: str = ""
    n_nodes: int = 1
    duration_steps: int = 0     # 0 = not coming back
    template: Optional["SubCluster"] = None

    def describe(self) -> str:
        back = f", back in {self.duration_steps}" if self.duration_steps else ""
        return f"Preemption@{self.step}({self.subcluster} -{self.n_nodes}{back})"


def apply_event(cluster: HeteroCluster, event: ClusterEvent) -> HeteroCluster:
    """Pure fold step: new cluster value after ``event``."""
    if isinstance(event, (NodeFailure, Preemption)):
        return remove_nodes(cluster, event.subcluster, event.n_nodes)
    if isinstance(event, NodeJoin):
        names = {s.name for s in cluster.subclusters}
        if event.subcluster not in names and event.template is not None:
            sub = dataclasses.replace(event.template, n_nodes=event.n_nodes)
            return dataclasses.replace(
                cluster, subclusters=cluster.subclusters + (sub,))
        return add_nodes(cluster, event.subcluster, event.n_nodes)
    if isinstance(event, BandwidthShift):
        return with_cross_bw(cluster, event.cross_bw)
    if isinstance(event, Straggler):
        return set_efficiency(cluster, event.subcluster, event.efficiency)
    raise TypeError(f"unknown cluster event {event!r}")


# ---------------------------------------------------------------------------
# Traces
# ---------------------------------------------------------------------------


@dataclass
class EventTrace:
    """Events sorted by step.  Scheduled returns of ``Preemption`` events are
    materialized as ``NodeJoin`` entries at construction (``materialized=True``
    marks an already-expanded event list — e.g. one deserialized from JSON —
    so re-construction doesn't duplicate the returns)."""
    events: List[ClusterEvent] = field(default_factory=list)
    materialized: bool = False

    def __post_init__(self):
        expanded: List[ClusterEvent] = []
        for e in self.events:
            expanded.append(e)
            if not self.materialized and isinstance(e, Preemption) \
                    and e.duration_steps > 0:
                expanded.append(NodeJoin(step=e.step + e.duration_steps,
                                         subcluster=e.subcluster,
                                         n_nodes=e.n_nodes,
                                         template=e.template))
        self.events = sorted(expanded, key=lambda e: e.step)
        self.materialized = True

    def at(self, step: int) -> List[ClusterEvent]:
        return [e for e in self.events if e.step == step]

    def cluster_at(self, base: HeteroCluster, step: int) -> HeteroCluster:
        """Fleet state just before step ``step`` begins (events at ``step``
        itself already applied — they strike at the step boundary)."""
        cl = base
        for e in self.events:
            if e.step > step:
                break
            cl = apply_event(cl, e)
        return cl

    @property
    def last_step(self) -> int:
        return self.events[-1].step if self.events else 0

    def describe(self) -> str:
        return " -> ".join(e.describe() for e in self.events) or "(empty)"


def paper_trace(cluster: HeteroCluster, *,
                fail_step: int = 60, bw_step: int = 100,
                recover_step: int = 150,
                degraded_gbps: float = 2.0) -> EventTrace:
    """The benchmark's scripted disruption: one node of the *weakest*
    sub-cluster with spare nodes fails, then the cross link congests, then
    both recover.  (Single-node sub-clusters are skipped so the rejoin can
    resolve the name; a whole-sub-cluster outage needs ``NodeJoin.template``.)
    """
    candidates = [s for s in cluster.subclusters if s.n_nodes >= 2] \
        or list(cluster.subclusters)
    weakest = min(candidates, key=lambda s: s.device.effective_flops)
    return EventTrace([
        NodeFailure(step=fail_step, subcluster=weakest.name, n_nodes=1),
        BandwidthShift(step=bw_step, cross_bw=degraded_gbps * GBPS),
        NodeJoin(step=recover_step, subcluster=weakest.name, n_nodes=1,
                 template=weakest),
        BandwidthShift(step=recover_step, cross_bw=cluster.cross_bw),
    ])


def random_trace(cluster: HeteroCluster, n_steps: int, seed: int = 0, *,
                 p_failure: float = 0.002, p_preempt: float = 0.002,
                 p_bw_shift: float = 0.004, p_straggler: float = 0.004,
                 mean_outage_steps: int = 40) -> EventTrace:
    """Seeded fleet-dynamics generator (per-step Bernoulli hazards).

    Failures schedule their own recovery (mean ``mean_outage_steps``,
    geometric); bandwidth shifts draw uniformly in [0.3, 1.2] x nominal;
    stragglers draw efficiency in [0.4, 0.95].  Deterministic per seed.
    """
    rng = random.Random(seed)
    names = [s.name for s in cluster.subclusters]
    avail: Dict[str, int] = {s.name: s.n_nodes for s in cluster.subclusters}
    events: List[ClusterEvent] = []
    for step in range(1, n_steps):
        r = rng.random()
        if r < p_failure + p_preempt:   # preempt = upper part of the band
            name = rng.choice(names)
            if avail[name] <= 1:
                continue    # never drop a sub-cluster's last node
            outage = max(1, int(rng.expovariate(1.0 / mean_outage_steps)))
            preempt = r >= p_failure
            if preempt:
                events.append(Preemption(step=step, subcluster=name,
                                         n_nodes=1, duration_steps=outage))
            else:
                events.append(NodeFailure(step=step, subcluster=name))
                events.append(NodeJoin(step=step + outage, subcluster=name))
            avail[name] -= 1
            # NodeJoin return is accounted when its step is reached; keep the
            # conservative floor so concurrent hazards can't over-drain
        elif r < p_failure + p_preempt + p_bw_shift:
            events.append(BandwidthShift(
                step=step,
                cross_bw=cluster.cross_bw * rng.uniform(0.3, 1.2)))
        elif r < p_failure + p_preempt + p_bw_shift + p_straggler:
            events.append(Straggler(step=step, subcluster=rng.choice(names),
                                    efficiency=rng.uniform(0.4, 0.95)))
    return EventTrace(events)
