"""Measured-latency table: JSON-persisted, bucketed, mergeable.

One :class:`KernelMeasurement` records the median latency of one (device,
op, shape, block-config) cell.  Shapes are *bucketed* (every dim rounded up
to a power of two) so a table collected on a handful of representative
shapes can price nearby shapes via nearest-bucket interpolation scaled by
the FLOP (or element-count) ratio.

Merge policy (deterministic, commutative up to the stated tie-breaks): for
cells with the same (device, op, bucket, blocks) key the *newer*
``collected_at`` stamp wins; on equal stamps the lower latency wins (both
hosts measured the same cell — keep the better-conditioned run).  Entries
are kept sorted so serialization is canonical regardless of insert order.

This module is pure Python (no jax) — the planner imports it freely.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

TABLE_SCHEMA = 1


def _pow2_ceil(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def shape_bucket(shape: Iterable[int]) -> Tuple[int, ...]:
    """Canonical bucket for a shape: each dim rounded up to a power of two."""
    return tuple(_pow2_ceil(d) for d in shape)


@dataclass(frozen=True)
class KernelMeasurement:
    """One measured cell of the latency table."""

    device: str                      # device fingerprint, e.g. "tpu:TPU v5e"
    op: str                          # op name in the harness registry
    shape: Tuple[int, ...]           # the shape actually measured
    median_s: float                  # median wall-clock seconds per call
    trials: int                      # number of timed trials behind the median
    flops: float                     # analytic FLOP count at `shape` (0 = n/a)
    blocks: Optional[Tuple[int, ...]]  # block config measured (None = default)
    collected_at: float              # unix seconds (staleness stamp)
    host: str                        # hostname the measurement came from

    @property
    def bucket(self) -> Tuple[int, ...]:
        return shape_bucket(self.shape)

    @property
    def key(self) -> Tuple:
        return (self.device, self.op, self.bucket,
                self.blocks if self.blocks is not None else ())

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["shape"] = list(self.shape)
        d["blocks"] = None if self.blocks is None else list(self.blocks)
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "KernelMeasurement":
        return KernelMeasurement(
            device=str(d["device"]), op=str(d["op"]),
            shape=tuple(int(x) for x in d["shape"]),
            median_s=float(d["median_s"]), trials=int(d["trials"]),
            flops=float(d.get("flops", 0.0)),
            blocks=(None if d.get("blocks") is None
                    else tuple(int(x) for x in d["blocks"])),
            collected_at=float(d.get("collected_at", 0.0)),
            host=str(d.get("host", "")))


def _bucket_dist(a: Tuple[int, ...], b: Tuple[int, ...]) -> float:
    return sum(abs(math.log2(x) - math.log2(y)) for x, y in zip(a, b))


class LatencyTable:
    """A set of :class:`KernelMeasurement` with lookup/merge/persistence."""

    def __init__(self, entries: Optional[Iterable[KernelMeasurement]] = None):
        self.entries: List[KernelMeasurement] = []
        for e in entries or ():
            self.add(e)

    # -- construction -------------------------------------------------------

    def add(self, m: KernelMeasurement) -> None:
        """Insert, applying the merge policy against any same-key entry."""
        for i, e in enumerate(self.entries):
            if e.key == m.key:
                self.entries[i] = self._prefer(e, m)
                break
        else:
            self.entries.append(m)
        self.entries.sort(key=lambda e: (e.device, e.op, e.bucket,
                                         e.blocks or (), e.shape))

    @staticmethod
    def _prefer(a: KernelMeasurement, b: KernelMeasurement) -> KernelMeasurement:
        # newer stamp wins; equal stamps -> lower latency wins
        if a.collected_at != b.collected_at:
            return a if a.collected_at > b.collected_at else b
        return a if a.median_s <= b.median_s else b

    def merge(self, other: "LatencyTable") -> "LatencyTable":
        out = LatencyTable(self.entries)
        for e in other.entries:
            out.add(e)
        return out

    # -- queries ------------------------------------------------------------

    def fresh(self, max_age_s: float = 0.0,
              now: Optional[float] = None) -> "LatencyTable":
        """Entries no older than ``max_age_s`` (0 = everything is fresh)."""
        if not max_age_s:
            return self
        if now is None:
            now = max((e.collected_at for e in self.entries), default=0.0)
        return LatencyTable(e for e in self.entries
                            if now - e.collected_at <= max_age_s)

    def devices(self) -> List[str]:
        return sorted({e.device for e in self.entries})

    def for_device(self, device: str) -> List[KernelMeasurement]:
        return [e for e in self.entries if e.device == device]

    def lookup(self, device: str, op: str,
               shape: Iterable[int]) -> Optional[KernelMeasurement]:
        """Nearest-bucket entry for (device, op, shape); None if uncovered.

        Exact bucket match wins; otherwise the same-rank entry with the
        smallest log2 bucket distance (deterministic tie-break on the
        bucket tuple, then on the block config)."""
        shape = tuple(int(d) for d in shape)
        want = shape_bucket(shape)
        cands = [e for e in self.entries
                 if e.device == device and e.op == op
                 and len(e.bucket) == len(want)]
        if not cands:
            return None
        return min(cands, key=lambda e: (_bucket_dist(e.bucket, want),
                                         e.bucket, e.blocks or ()))

    def estimate_s(self, device: str, op: str, shape: Iterable[int],
                   flops: Optional[float] = None) -> Optional[float]:
        """Interpolated latency estimate at ``shape`` (None if uncovered).

        Scales the nearest bucket's measured latency by the FLOP ratio when
        the caller supplies the query shape's FLOP count (and the entry
        recorded one), else by the element-count ratio."""
        shape = tuple(int(d) for d in shape)
        e = self.lookup(device, op, shape)
        if e is None:
            return None
        if flops is not None and e.flops > 0:
            return e.median_s * (flops / e.flops)
        ours = 1
        for d in shape:
            ours *= max(1, d)
        theirs = 1
        for d in e.shape:
            theirs *= max(1, d)
        return e.median_s * (ours / theirs)

    def best_blocks(self, device: str, op: str,
                    shape: Iterable[int]) -> Optional[Tuple[int, ...]]:
        """Winning block config at the nearest bucket (None = untuned/default)."""
        shape = tuple(int(d) for d in shape)
        want = shape_bucket(shape)
        cands = [e for e in self.entries
                 if e.device == device and e.op == op
                 and len(e.bucket) == len(want)]
        if not cands:
            return None
        dmin = min(_bucket_dist(e.bucket, want) for e in cands)
        at_bucket = [e for e in cands if _bucket_dist(e.bucket, want) == dmin]
        winner = min(at_bucket, key=lambda e: (e.median_s, e.blocks or ()))
        return winner.blocks

    def fingerprint(self) -> str:
        """Stable content hash — joins the profiler's cost-cache key."""
        import hashlib
        blob = json.dumps([e.to_dict() for e in self.entries], sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"schema": TABLE_SCHEMA,
                "entries": [e.to_dict() for e in self.entries]}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "LatencyTable":
        if int(d.get("schema", TABLE_SCHEMA)) > TABLE_SCHEMA:
            raise ValueError(
                f"latency table schema {d.get('schema')} is newer than "
                f"supported ({TABLE_SCHEMA})")
        return LatencyTable(KernelMeasurement.from_dict(e)
                            for e in d.get("entries", []))

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "LatencyTable":
        with open(path) as f:
            return LatencyTable.from_dict(json.load(f))

    def __len__(self) -> int:
        return len(self.entries)
