"""Block-size autotuner: sweep tiling grids, record winners, install them.

For each (device, op, shape) the sweep measures every block config in the
op's grid (the default config is always a member, so the winner is never
slower than the default *on the same measurements*) and keeps the argmin
median with a deterministic tie-break on the block tuple.  Winners land in
the :class:`~repro.kbench.table.LatencyTable` as ordinary measurements —
``best_blocks`` reads them back out, and :func:`install` pushes them into
the tuned-block registry in ``kernels/ops.py`` so entry points called with
``block_q=None``-style defaults pick them up transparently.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.kbench.table import LatencyTable


@dataclass(frozen=True)
class SweepResult:
    op: str
    shape: Tuple[int, ...]
    device: str
    best_blocks: Optional[Tuple[int, ...]]
    best_s: float
    default_blocks: Optional[Tuple[int, ...]]
    default_s: float
    sweep: Tuple[Tuple[Optional[Tuple[int, ...]], float], ...]

    @property
    def speedup(self) -> float:
        """Default-vs-winner latency ratio (>= 1.0 by construction)."""
        return self.default_s / self.best_s if self.best_s > 0 else 1.0


def sweep(op: str, shape: Sequence[int], *, trials: int = 5, warmup: int = 2,
          interpret: Optional[bool] = None, seed: int = 0) -> SweepResult:
    """Measure every block config in the op's grid at ``shape``."""
    from repro.kbench import harness

    spec = harness.OPS[op]
    shape = tuple(int(d) for d in shape)
    grid = list(spec.block_grid(shape))
    if spec.default_blocks is not None and spec.default_blocks not in grid:
        grid.append(spec.default_blocks)
    results = []
    for blocks in grid:
        res = harness.bench_op(op, shape, blocks=blocks, trials=trials,
                               warmup=warmup, interpret=interpret, seed=seed)
        results.append((blocks, res.median_s))
    best_blocks, best_s = min(results, key=lambda r: (r[1], r[0] or ()))
    default_s = next(s for b, s in results if b == spec.default_blocks)
    return SweepResult(op=op, shape=shape,
                       device=harness.device_fingerprint(interpret),
                       best_blocks=best_blocks, best_s=best_s,
                       default_blocks=spec.default_blocks,
                       default_s=default_s, sweep=tuple(results))


def collect_autotuned(ops_to_run: Optional[Sequence[str]] = None, *,
                      shapes: str = "tiny", trials: int = 5, warmup: int = 2,
                      interpret: Optional[bool] = None, seed: int = 0,
                      device: Optional[str] = None,
                      collected_at: Optional[float] = None,
                      host: Optional[str] = None,
                      ) -> Tuple[LatencyTable, List[SweepResult]]:
    """Sweep every requested op; the table records the winning cells."""
    from repro.kbench import harness

    table = LatencyTable()
    sweeps: List[SweepResult] = []
    for name in ops_to_run or sorted(harness.OPS):
        spec = harness.OPS[name]
        shape = spec.tiny_shape if shapes == "tiny" else spec.default_shape
        sw = sweep(name, shape, trials=trials, warmup=warmup,
                   interpret=interpret, seed=seed)
        sweeps.append(sw)
        # the sweep already timed the winner — record it without re-running
        res = harness.BenchResult(op=name, shape=shape,
                                  blocks=sw.best_blocks,
                                  median_s=sw.best_s,
                                  trials_s=(sw.best_s,) * max(1, trials),
                                  flops=spec.flops(shape), device=sw.device)
        table.add(harness.measurement(res, device=device,
                                      collected_at=collected_at, host=host))
    return table, sweeps


def best_blocks(op: str, shape: Sequence[int], device: str,
                table: LatencyTable) -> Optional[Tuple[int, ...]]:
    """Winning block config recorded in ``table`` (None = untuned)."""
    return table.best_blocks(device, op, shape)


def install(table: LatencyTable, device: Optional[str] = None) -> int:
    """Push a table's winners into the ops tuned-block registry.

    Only entries for ``device`` (default: the current process's fingerprint)
    are installed — a table merged across hosts holds cells for devices this
    process doesn't have.  Returns the number of installed configs."""
    from repro.kbench import harness
    from repro.kernels import ops

    device = device or harness.device_fingerprint()
    n = 0
    for e in table.for_device(device):
        if e.blocks:
            ops.set_tuned_blocks(e.op, e.shape, e.blocks)
            n += 1
    return n
