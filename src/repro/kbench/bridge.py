"""Bridge: measured latency table -> planner cost model.

:class:`KBenchConfig` is the serializable knob (``PlannerConfig.kbench`` /
``HarpConfig.kbench``); :class:`KBenchModel` is the live object the planner
builds from it.  The model answers one question for the cost model — "what
MFU does this device *actually* achieve?" — as the flop-weighted achieved
throughput over the device's fresh table cells divided by peak.  That
measured anchor replaces the spec-sheet ``base_mfu`` in ``costmodel._mfu``;
the telemetry ``efficiency`` scale and tp/dp decays still apply on top, so
runtime calibration composes with plan-time measurement.

Fallback semantics (invariant: *fallback never errors*): a device with no
fresh table cells — wrong fingerprint, stale entries, empty table, missing
file — prices exactly as the analytic model; no exception escapes lookup.
``kbench=None`` plans are bit-identical to pre-kbench plans (off-state
invariant, pinned in tests).

Pure Python — no jax.  Collecting tables is ``harness``/``autotune``'s job.
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.kbench.table import LatencyTable


@dataclass(frozen=True)
class KBenchConfig:
    """Serializable measured-pricing knob.

    table_path:  JSON latency table on disk (missing file -> empty table,
                 i.e. full analytic fallback, never an error).
    table:       inline table document (``LatencyTable.to_dict`` form) —
                 merged over ``table_path`` when both are given; makes Plan
                 artifacts self-contained.
    max_age_s:   staleness horizon for measurements (0 = never stale).
    device_map:  DeviceProfile.name -> table device fingerprint.  Planner
                 devices are fleet archetypes ("A100-40G") while tables are
                 stamped with what the harness ran on ("gpu:NVIDIA A100...");
                 unmapped names are looked up verbatim.
    """

    table_path: Optional[str] = None
    table: Optional[Dict[str, Any]] = None
    max_age_s: float = 0.0
    device_map: Optional[Dict[str, str]] = None

    def to_dict(self) -> Dict[str, Any]:
        return {"table_path": self.table_path, "table": self.table,
                "max_age_s": self.max_age_s, "device_map": self.device_map}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "KBenchConfig":
        return KBenchConfig(
            table_path=d.get("table_path"), table=d.get("table"),
            max_age_s=float(d.get("max_age_s", 0.0)),
            device_map=(None if d.get("device_map") is None
                        else dict(d["device_map"])))


# measured MFU is clamped into a sane band: a corrupted cell can't produce
# a zero/negative denominator or a >100% "efficiency"
_MFU_MIN, _MFU_MAX = 1e-6, 1.0


class KBenchModel:
    """Live measured-pricing model built from a :class:`KBenchConfig`."""

    def __init__(self, cfg: KBenchConfig):
        self.cfg = cfg
        table = LatencyTable()
        if cfg.table_path and os.path.exists(cfg.table_path):
            table = table.merge(LatencyTable.load(cfg.table_path))
        if cfg.table:
            table = table.merge(LatencyTable.from_dict(cfg.table))
        self.table = table
        self._fresh = table.fresh(cfg.max_age_s)
        self._mfu_cache: Dict[str, Optional[float]] = {}

    # -- identity -----------------------------------------------------------

    def fingerprint(self) -> str:
        """Joins the profiler's cost-cache key: everything pricing reads."""
        blob = json.dumps({"table": self._fresh.fingerprint(),
                           "max_age_s": self.cfg.max_age_s,
                           "device_map": self.cfg.device_map},
                          sort_keys=True)
        return "kbench:" + hashlib.sha256(blob.encode()).hexdigest()[:16]

    def device_key(self, profile_name: str) -> str:
        if self.cfg.device_map and profile_name in self.cfg.device_map:
            return self.cfg.device_map[profile_name]
        return profile_name

    # -- pricing ------------------------------------------------------------

    def measured_mfu(self, sub) -> Optional[float]:
        """Achieved MFU for this sub-cluster's device; None = uncovered.

        Flop-weighted over the device's fresh cells: total measured FLOPs /
        total measured seconds, divided by the device's peak.  Cells without
        a FLOP count (flops=0) can't be weighted and are skipped."""
        name = sub.device.name
        if name not in self._mfu_cache:
            self._mfu_cache[name] = self._compute_mfu(sub)
        return self._mfu_cache[name]

    def _compute_mfu(self, sub) -> Optional[float]:
        entries = [e for e in self._fresh.for_device(self.device_key(sub.device.name))
                   if e.flops > 0 and e.median_s > 0]
        if not entries:
            return None
        achieved = sum(e.flops for e in entries) / sum(e.median_s for e in entries)
        return min(_MFU_MAX, max(_MFU_MIN, achieved / sub.device.peak_flops))

    def covered_devices(self) -> Dict[str, float]:
        """Table device fingerprint -> achieved FLOP/s (diagnostics)."""
        out: Dict[str, float] = {}
        for dev in self._fresh.devices():
            entries = [e for e in self._fresh.for_device(dev)
                       if e.flops > 0 and e.median_s > 0]
            if entries:
                out[dev] = (sum(e.flops for e in entries)
                            / sum(e.median_s for e in entries))
        return out

    def estimate_s(self, device_name: str, op: str, shape,
                   flops: Optional[float] = None) -> Optional[float]:
        """Nearest-bucket latency estimate through the device map."""
        return self._fresh.estimate_s(self.device_key(device_name), op,
                                      shape, flops=flops)

    # -- profiler hook ------------------------------------------------------

    def as_measure_fn(self, cfgm=None, comm=None):
        """Adapt the table into the ``ZeroRedundantProfiler.measure_fn``
        contract: ``fn(layers, sub, mesh, mb_tokens) -> StageCost`` priced
        with the measured MFU anchor (analytic fallback when uncovered)."""
        from repro.core.costmodel import CostModelConfig, stage_cost

        cfgm = cfgm if cfgm is not None else CostModelConfig()

        def fn(layers, sub, mesh, mb_tokens):
            return stage_cost(layers, sub, mesh, mb_tokens, cfgm,
                              comm=comm, kbench=self)

        return fn

    def describe(self) -> str:
        lines = [f"kbench table: {len(self.table)} cells "
                 f"({len(self._fresh)} fresh), "
                 f"devices: {', '.join(self.table.devices()) or '(none)'}"]
        for dev, flops in sorted(self.covered_devices().items()):
            lines.append(f"  {dev}: achieved {flops / 1e12:.3f} TFLOP/s")
        return "\n".join(lines)
