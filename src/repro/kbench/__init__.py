"""repro.kbench — measured-kernel cost model.

Closes the loop from the Pallas kernel zoo to the planner (ROADMAP item 5):

  - ``harness``  — deterministic microbenchmark runner for the fused ops in
    ``kernels/ops.py`` (seeded inputs, warmup + block_until_ready,
    median-of-k trials, interpret-mode path so it runs off-GPU in CI);
  - ``autotune`` — block-size autotuner sweeping the (block_q, block_k)-style
    tiling grids per (device, op, shape), installing winners into the kernel
    entry points' tuned-block registry;
  - ``table``    — JSON-persisted per-(device_fingerprint, op, shape-bucket)
    measured-latency table with nearest-bucket interpolation, staleness
    stamps, and a deterministic cross-host merge policy;
  - ``bridge``   — adapts the table into ``ZeroRedundantProfiler.measure_fn``
    and the cost model so ``PlannerConfig.kbench=KBenchConfig(...)`` prices
    DP-search stages from measurements, falling back to the analytic
    estimate for uncovered cells.  ``kbench=None`` is bit-identical to the
    analytic-only planner (off-state invariant, pinned in tests).

Layering: ``table``/``bridge`` are pure Python (safe for the numpy-only
planner); ``harness``/``autotune`` import jax and are only pulled in when
actually measuring.
"""
from repro.kbench.table import KernelMeasurement, LatencyTable, shape_bucket
from repro.kbench.bridge import KBenchConfig, KBenchModel

__all__ = [
    "KernelMeasurement",
    "LatencyTable",
    "shape_bucket",
    "KBenchConfig",
    "KBenchModel",
]
