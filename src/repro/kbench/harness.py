"""Deterministic microbenchmark harness for the fused Pallas ops.

Measures the public entry points in ``kernels/ops.py`` (flash attention,
SSD intra-chunk, rmsnorm) — seeded inputs, jit + warmup, ``block_until_ready``
around every timed call, median of k trials.  ``interpret=None`` resolves the
same way the ops do (Python interpretation of the kernel body off-TPU), so
the harness runs anywhere CI does; the resulting fingerprints are tagged
``:interpret`` so tables collected that way are never mistaken for hardware
measurements.

Shape-key conventions (shared with the tuned-block registry in ops.py):

  - ``flash_attention``: (B, T, S, H, KV, D)
  - ``rmsnorm``:         (rows, D)
  - ``ssd_intra``:       (B, nc, Q, H, P, N)

This module imports jax — keep it out of the planner path (``table``/
``bridge`` stay pure).
"""
from __future__ import annotations

import socket
import statistics
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kbench.table import KernelMeasurement, LatencyTable


# ---------------------------------------------------------------------------
# Op registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OpSpec:
    name: str
    make_inputs: "callable"          # (shape, seed) -> tuple of arrays
    call: "callable"                 # (args, blocks, interpret) -> array
    flops: "callable"                # (shape,) -> float
    default_blocks: Optional[Tuple[int, ...]]
    block_grid: "callable"           # (shape,) -> list of block tuples
    tiny_shape: Tuple[int, ...]
    default_shape: Tuple[int, ...]


def _rng(seed: int):
    return np.random.default_rng(seed)


def _flash_inputs(shape, seed):
    B, T, S, H, KV, D = shape
    r = _rng(seed)
    q = jnp.asarray(r.standard_normal((B, T, H, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KV, D)), jnp.float32)
    return (q, k, v)


def _flash_call(args, blocks, interpret):
    bq, bk = blocks if blocks else (None, None)
    return ops.flash_attention(*args, causal=True, block_q=bq, block_k=bk,
                               interpret=interpret)


def _flash_flops(shape):
    B, T, S, H, KV, D = shape
    # two (T, S) x D matmuls per head, causal halves the live scores
    return 4.0 * B * H * T * S * D * 0.5


def _flash_grid(shape):
    _, T, S, _, _, _ = shape
    cand = (64, 128, 256)
    return [(bq, bk) for bq in cand for bk in cand]


def _rmsnorm_inputs(shape, seed):
    rows, D = shape
    r = _rng(seed)
    x = jnp.asarray(r.standard_normal((rows, D)), jnp.float32)
    w = jnp.asarray(r.standard_normal((D,)), jnp.float32)
    return (x, w)


def _rmsnorm_call(args, blocks, interpret):
    br = blocks[0] if blocks else None
    return ops.rmsnorm(*args, block_rows=br, interpret=interpret)


def _rmsnorm_flops(shape):
    rows, D = shape
    return 4.0 * rows * D


def _rmsnorm_grid(shape):
    rows, _ = shape
    return [(b,) for b in (32, 64, 128, 256) if b <= max(32, rows)]


def _ssd_inputs(shape, seed):
    B, nc, Q, H, P, N = shape
    r = _rng(seed)
    xc = jnp.asarray(r.standard_normal((B, nc, Q, H, P)), jnp.float32)
    dtc = jnp.asarray(r.uniform(0.1, 1.0, (B, nc, Q, H)), jnp.float32)
    cum = jnp.asarray(np.cumsum(
        r.uniform(-0.1, 0.0, (B, nc, Q, H)), axis=2), jnp.float32)
    Bc = jnp.asarray(r.standard_normal((B, nc, Q, N)), jnp.float32)
    Cc = jnp.asarray(r.standard_normal((B, nc, Q, N)), jnp.float32)
    return (xc, dtc, cum, Bc, Cc)


def _ssd_call(args, blocks, interpret):
    return ops.ssd_intra(*args, interpret=interpret)


def _ssd_flops(shape):
    B, nc, Q, H, P, N = shape
    return 2.0 * B * nc * H * Q * Q * (N + P)


OPS: Dict[str, OpSpec] = {
    "flash_attention": OpSpec(
        name="flash_attention", make_inputs=_flash_inputs, call=_flash_call,
        flops=_flash_flops, default_blocks=(128, 128), block_grid=_flash_grid,
        tiny_shape=(1, 128, 128, 2, 2, 32),
        default_shape=(2, 512, 512, 16, 16, 64)),
    "rmsnorm": OpSpec(
        name="rmsnorm", make_inputs=_rmsnorm_inputs, call=_rmsnorm_call,
        flops=_rmsnorm_flops, default_blocks=(128,), block_grid=_rmsnorm_grid,
        tiny_shape=(256, 128), default_shape=(4096, 2048)),
    "ssd_intra": OpSpec(
        name="ssd_intra", make_inputs=_ssd_inputs, call=_ssd_call,
        flops=_ssd_flops, default_blocks=None, block_grid=lambda shape: [None],
        tiny_shape=(1, 2, 64, 2, 32, 32),
        default_shape=(2, 4, 256, 8, 64, 128)),
}


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BenchResult:
    op: str
    shape: Tuple[int, ...]
    blocks: Optional[Tuple[int, ...]]
    median_s: float
    trials_s: Tuple[float, ...]
    flops: float
    device: str


def device_fingerprint(interpret: Optional[bool] = None) -> str:
    """Stable identity of what a measurement actually ran on.

    ``backend:device_kind``, suffixed ``:interpret`` when the kernel body
    runs under the Pallas Python interpreter rather than compiled Mosaic."""
    kind = jax.devices()[0].device_kind
    fp = f"{jax.default_backend()}:{kind}"
    if ops._auto_interpret(interpret):
        fp += ":interpret"
    return fp


def bench_op(op: str, shape: Sequence[int], *,
             blocks: Optional[Tuple[int, ...]] = None,
             trials: int = 5, warmup: int = 2,
             interpret: Optional[bool] = None,
             seed: int = 0) -> BenchResult:
    """Median-of-``trials`` latency of one (op, shape, blocks) cell."""
    spec = OPS[op]
    shape = tuple(int(d) for d in shape)
    args = spec.make_inputs(shape, seed)
    interp = ops._auto_interpret(interpret)

    fn = jax.jit(lambda *a: spec.call(a, blocks, interp))
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args))
    samples: List[float] = []
    for _ in range(max(1, trials)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return BenchResult(op=op, shape=shape, blocks=blocks,
                       median_s=float(statistics.median(samples)),
                       trials_s=tuple(samples), flops=spec.flops(shape),
                       device=device_fingerprint(interpret))


def measurement(res: BenchResult, *, device: Optional[str] = None,
                collected_at: Optional[float] = None,
                host: Optional[str] = None) -> KernelMeasurement:
    """Convert a BenchResult into a table row (stamping time + host)."""
    return KernelMeasurement(
        device=device or res.device, op=res.op, shape=res.shape,
        median_s=res.median_s, trials=len(res.trials_s), flops=res.flops,
        blocks=res.blocks,
        collected_at=time.time() if collected_at is None else collected_at,
        host=host or socket.gethostname())


def collect(ops_to_run: Optional[Sequence[str]] = None, *,
            shapes: str = "tiny", trials: int = 5, warmup: int = 2,
            interpret: Optional[bool] = None, seed: int = 0,
            device: Optional[str] = None,
            collected_at: Optional[float] = None,
            host: Optional[str] = None) -> LatencyTable:
    """Measure every requested op at its canonical shape (default blocks).

    ``shapes`` picks the canonical set: "tiny" (CI/interpret-sized) or
    "default" (hardware-sized).  For the block-sweeping variant see
    ``repro.kbench.autotune.collect_autotuned``."""
    table = LatencyTable()
    for name in ops_to_run or sorted(OPS):
        spec = OPS[name]
        shape = spec.tiny_shape if shapes == "tiny" else spec.default_shape
        res = bench_op(name, shape, blocks=spec.default_blocks,
                       trials=trials, warmup=warmup, interpret=interpret,
                       seed=seed)
        table.add(measurement(res, device=device, collected_at=collected_at,
                              host=host))
    return table
