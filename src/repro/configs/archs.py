"""The 10 assigned architectures (exact published dims) + the paper's GPT configs."""
from __future__ import annotations

from repro.configs.base import ArchConfig

# --- dense ------------------------------------------------------------------

MINITRON_8B = ArchConfig(
    arch_id="minitron-8b", family="dense", source="arXiv:2407.14679",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000, activation="relu2",  # nemotron squared-ReLU
    rope_theta=10_000.0,
)

DEEPSEEK_7B = ArchConfig(
    arch_id="deepseek-7b", family="dense", source="arXiv:2401.02954",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, activation="swiglu",
)

GEMMA_2B = ArchConfig(
    arch_id="gemma-2b", family="dense", source="arXiv:2403.08295",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000, activation="geglu", tie_embeddings=True,
    scale_embed=True,
)

GEMMA3_12B = ArchConfig(
    arch_id="gemma3-12b", family="dense", source="hf:google/gemma-3 (unverified)",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144, activation="geglu", tie_embeddings=True,
    scale_embed=True,
    sliding_window=1024, local_global_ratio=5, max_position=131_072,
    rope_theta=1_000_000.0,
)

# --- MoE ----------------------------------------------------------------------

QWEN3_MOE = ArchConfig(
    arch_id="qwen3-moe-235b-a22b", family="moe", source="hf:Qwen/Qwen3 (hf)",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, activation="swiglu",
    n_experts=128, top_k=8, rope_theta=1_000_000.0,
)

GRANITE_MOE = ArchConfig(
    arch_id="granite-moe-1b-a400m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (hf)",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155, activation="swiglu",
    n_experts=32, top_k=8, tie_embeddings=True,
)

# --- SSM ------------------------------------------------------------------------

MAMBA2_27B = ArchConfig(
    arch_id="mamba2-2.7b", family="ssm", source="arXiv:2405.21060 (unverified)",
    n_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)

# --- VLM -------------------------------------------------------------------------

LLAMA32_VISION_90B = ArchConfig(
    arch_id="llama-3.2-vision-90b", family="vlm",
    source="hf:meta-llama/Llama-3.2-Vision (unverified)",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, activation="swiglu",
    cross_attn_every=5, n_image_tokens=1601, rope_theta=500_000.0,
)

# --- audio (enc-dec) ---------------------------------------------------------------

WHISPER_MEDIUM = ArchConfig(
    arch_id="whisper-medium", family="audio", source="arXiv:2212.04356 (unverified)",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865, activation="gelu",
    enc_layers=24, enc_frames=1500, rope_theta=0.0,  # absolute pos embeddings
)

# --- hybrid ---------------------------------------------------------------------------

ZAMBA2_7B = ArchConfig(
    arch_id="zamba2-7b", family="hybrid", source="arXiv:2411.15242 (unverified)",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000, activation="swiglu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    shared_attn_every=6,
)

# --- the paper's own GPT family (HAPT §6: 15B-39B, seq 1k, GBS 1024) ---------------

GPT_2B = ArchConfig(
    arch_id="gpt-2b", family="dense", source="HAPT paper §2.2.2 case study scale",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=51200, activation="gelu", max_position=1024,
)
GPT_15B = ArchConfig(
    arch_id="gpt-15b", family="dense", source="HAPT paper §6",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=20480, vocab_size=51200, activation="gelu", max_position=1024,
)
GPT_30B = ArchConfig(
    arch_id="gpt-30b", family="dense", source="HAPT paper §6",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=48,
    d_ff=24576, vocab_size=51200, activation="gelu", max_position=1024,
)
GPT_39B = ArchConfig(
    arch_id="gpt-39b", family="dense", source="HAPT paper §6 (#L=146 granularity)",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=64,
    d_ff=32768, vocab_size=51200, activation="gelu", max_position=1024,
)

ASSIGNED = (
    MINITRON_8B, DEEPSEEK_7B, GEMMA_2B, GEMMA3_12B, QWEN3_MOE, GRANITE_MOE,
    MAMBA2_27B, LLAMA32_VISION_90B, WHISPER_MEDIUM, ZAMBA2_7B,
)
PAPER = (GPT_2B, GPT_15B, GPT_30B, GPT_39B)
