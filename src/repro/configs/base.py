"""Architecture + shape configuration for the HAPT framework.

Every assigned architecture is described by one :class:`ArchConfig`. The config
is the single source of truth consumed by

- ``models.api.build_model``       (functional model construction)
- ``core.opgraph.build_op_sequence`` (planner IR: per-op flops/bytes/params)
- ``launch.dryrun``                (input_specs + sharded lower/compile)
- smoke tests                      (``cfg.reduced()``)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell.

    ``kind`` selects which step gets lowered: ``train`` -> train_step,
    ``prefill`` -> prefill forward, ``decode`` -> serve_step (one new token
    against a KV cache / SSM state of ``seq_len``).
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    # identity -------------------------------------------------------------
    arch_id: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'vlm' | 'audio' | 'hybrid'
    source: str = ""

    # transformer dims -------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 0              # per-expert ff dim for MoE
    vocab_size: int = 0
    activation: str = "swiglu"  # 'swiglu' | 'geglu' | 'relu2' | 'gelu'
    tie_embeddings: bool = False
    scale_embed: bool = False      # gemma-style sqrt(d_model) embedding scale
    norm_eps: float = 1e-6
    rope_theta: float = 10_000.0

    # attention pattern ------------------------------------------------------
    sliding_window: int = 0        # 0 -> full attention
    local_global_ratio: int = 0    # e.g. 5 -> 5 local layers per 1 global
    max_position: int = 131_072

    # MoE ----------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba-2 / SSD) ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): shared transformer block applied every k SSM layers ----
    shared_attn_every: int = 0

    # VLM: cross-attention image layers every k layers -------------------------
    cross_attn_every: int = 0
    n_image_tokens: int = 1601     # stub patch-embedding count (1 tile)

    # enc-dec (whisper) --------------------------------------------------------
    enc_layers: int = 0            # >0 -> encoder-decoder; n_layers = decoder
    enc_frames: int = 1500         # stub frame-embedding count

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # derived dims ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba-2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (needs sub-quadratic attention)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # local:global mixes (gemma3) are dominated by windowed layers
        return self.local_global_ratio > 0

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        """The assigned shape cells applicable to this arch."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            out.append(LONG_500K)
        return tuple(out)

    # parameter accounting ----------------------------------------------------
    def _attn_params(self) -> int:
        return self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model

    def _mlp_params(self, d_ff: Optional[int] = None) -> int:
        ff = self.d_ff if d_ff is None else d_ff
        gated = self.activation in ("swiglu", "geglu")
        n_in = 2 if gated else 1
        return self.d_model * ff * (n_in + 1)

    def _ssd_params(self) -> int:
        d_in, d_st, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
        # in_proj -> [z, x, B, C, dt], conv, norm, out_proj  (Mamba-2 fused proj)
        proj_in = self.d_model * (2 * d_in + 2 * d_st + nh)
        conv = self.ssm_conv * (d_in + 2 * d_st)
        out = d_in * self.d_model
        heads = 2 * nh  # A_log, D
        return proj_in + conv + out + heads + d_in

    def _block_params(self, layer_idx: int = 0) -> int:
        """Parameters of one repeated block (family-dependent)."""
        norm = 2 * self.d_model
        if self.family == "ssm":
            return self._ssd_params() + self.d_model
        if self.family == "hybrid":
            return self._ssd_params() + self.d_model
        if self.family == "moe":
            router = self.d_model * self.n_experts
            experts = self.n_experts * self._mlp_params()
            return self._attn_params() + router + experts + norm
        return self._attn_params() + self._mlp_params() + norm

    def param_count(self) -> int:
        """Total parameter count (embeddings included)."""
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        total = emb + self.d_model  # final norm
        total += self.n_layers * self._block_params()
        if self.family == "hybrid" and self.shared_attn_every:
            # one shared transformer block (attn + mlp), params counted once
            total += self._attn_params() + self._mlp_params() + 2 * self.d_model
            # per-application linear adapters from/to backbone width
            n_app = self.n_layers // self.shared_attn_every
            total += n_app * 2 * self.d_model * self.d_model
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * (self._attn_params() + 2 * self.d_model)
        if self.enc_layers:
            total += self.enc_layers * (self._attn_params() + self._mlp_params() + norm_p(self))
            total += self.n_layers * (self._attn_params() + self.d_model)  # dec cross-attn
            total += self.enc_frames * 0  # frontend stubbed
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        dense = self.param_count() - self.n_layers * self.n_experts * self._mlp_params()
        return int(dense + self.n_layers * self.top_k * self._mlp_params())

    # reduced config for smoke tests -------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config: one forward/train step runs on CPU."""
        r = {
            "n_layers": min(self.n_layers, 4),
            "d_model": 64,
            "n_heads": max(2, min(self.n_heads, 4)),
            "n_kv_heads": max(1, min(self.n_kv_heads, 2)),
            "head_dim": 16,
            "d_ff": 128,
            "vocab_size": 512,
            "max_position": 1024,
        }
        if self.n_experts:
            r["n_experts"] = 4
            r["top_k"] = 2
        if self.ssm_state:
            r["ssm_state"] = 16
            r["ssm_head_dim"] = 16
            r["ssm_chunk"] = 32
        if self.sliding_window:
            r["sliding_window"] = 64
        if self.local_global_ratio:
            r["local_global_ratio"] = 2
            r["n_layers"] = 6  # two groups of (2 local + 1 global)
        if self.shared_attn_every:
            r["shared_attn_every"] = 2
        if self.cross_attn_every:
            r["cross_attn_every"] = 2
            r["n_image_tokens"] = 16
        if self.enc_layers:
            r["enc_layers"] = 2
            r["enc_frames"] = 32
        return dataclasses.replace(self, arch_id=self.arch_id + "-smoke", **r)


def norm_p(cfg: ArchConfig) -> int:
    return 2 * cfg.d_model
