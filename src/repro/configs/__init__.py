"""Config registry: ``get_config("minitron-8b")``, ``get_shape("train_4k")``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ALL_SHAPES,
    ArchConfig,
    SHAPES,
    ShapeSpec,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
)
from repro.configs import archs as _archs

_REGISTRY: Dict[str, ArchConfig] = {
    c.arch_id: c for c in (*_archs.ASSIGNED, *_archs.PAPER)
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def list_archs(assigned_only: bool = False) -> List[str]:
    src = _archs.ASSIGNED if assigned_only else _REGISTRY.values()
    return [c.arch_id for c in src]


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


__all__ = [
    "ArchConfig", "ShapeSpec", "get_config", "get_shape", "list_archs",
    "register", "ALL_SHAPES", "SHAPES",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
