"""Logical-axis sharding rules -> PartitionSpecs for parameters, optimizer
states, activations and KV caches — and the lowering of a planner
:class:`~repro.core.strategy.IntraOpPlan` to an executable mesh.

Parameter specs are derived from leaf *names* in the model pytree (every
model family uses the same naming vocabulary), with trailing-dims matching:
a rule gives the spec of the rightmost dims; any extra leading dims (layer
stacks, expert dims handled explicitly, pipeline-stage dims) are padded with
``None`` / the stage axis.

Axes of the production mesh: ``data`` (DP + FSDP), ``model`` (TP/SP),
``pod`` (pipeline, multi-pod only).

Intra-op lowering (:func:`mesh_from_intra_op`, :func:`batch_shard_sizes`):
one pipeline stage's plan becomes a ``(data=dp, model=tp)`` mesh over the
stage's devices, and the plan's shard ratios become integer per-shard batch
sizes (largest-remainder apportionment — sizes always sum to the batch).
Invariants: ``shard_ratios`` sum to 1 (validated here, units dimensionless);
the degenerate ``degree == 1`` plan lowers to a 1x1 mesh, i.e. a no-op.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.strategy import IntraOpPlan

FSDP = "data"
TP = "model"

# rule: leaf name -> trailing-dim partition entries
_PARAM_RULES: Dict[str, Tuple] = {
    # embeddings / head
    "embed": (TP, FSDP),           # (V, d)
    "lm_head": (FSDP, TP),         # (d, V)
    "pos_embed": (None, FSDP),
    # attention / mlp / adapters (column-parallel in, row-parallel out)
    "wq": (FSDP, TP), "wk": (FSDP, TP), "wv": (FSDP, TP),
    "wo": (TP, FSDP),
    "w_up": (FSDP, TP), "w_gate": (FSDP, TP), "w_down": (TP, FSDP),
    "adapt_in": (FSDP, TP), "adapt_out": (TP, FSDP),
    # MoE (expert dim -> FSDP axis = expert parallelism inside the pod)
    "router": (FSDP, None),
    "moe:w_up": (FSDP, None, TP), "moe:w_gate": (FSDP, None, TP),
    "moe:w_down": (FSDP, TP, None),
    # SSM
    "in_proj": (FSDP, TP),
    "conv_w": (None, TP), "conv_b": (TP,),
    "A_log": (None,), "D": (None,), "dt_bias": (None,),
    "norm_w": (TP,), "out_proj": (TP, FSDP),
    # norms / scalars
    "ln": (None,), "ln1": (None,), "ln2": (None,), "ln_x": (None,),
    "final_norm": (None,), "enc_norm": (None,),
    "gate_a": (), "gate_m": (),
}


def _leaf_spec(path: Tuple[str, ...], ndim: int) -> P:
    name = path[-1]
    in_moe = any(p in ("moe",) for p in path)
    rule = None
    if in_moe and f"moe:{name}" in _PARAM_RULES:
        rule = _PARAM_RULES[f"moe:{name}"]
    elif name in _PARAM_RULES:
        rule = _PARAM_RULES[name]
    if rule is None:
        raise KeyError(f"no sharding rule for param {'/'.join(path)}")
    pad = ndim - len(rule)
    assert pad >= 0, f"{path}: rule {rule} longer than ndim {ndim}"
    return P(*([None] * pad), *rule)


def _tree_paths(tree) -> Any:
    """Map each leaf to its (path, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: (tuple(_key_str(k) for k in kp), leaf), tree)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def param_pspecs(params_tree) -> Any:
    """PartitionSpec pytree matching ``params_tree`` (shapes or arrays)."""
    def one(kp, leaf):
        path = tuple(_key_str(k) for k in kp)
        ndim = len(leaf.shape)
        return _leaf_spec(path, ndim)
    return jax.tree_util.tree_map_with_path(one, params_tree)


def param_shardings(params_tree, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_tree))


def staged_param_pspecs(params_tree, stage_axis: str = "pod") -> Any:
    """Specs for pipeline-staged params: leading stage dim on every leaf."""
    def one(kp, leaf):
        path = tuple(_key_str(k) for k in kp)
        spec = _leaf_spec(path, len(leaf.shape) - 1)
        return P(stage_axis, *spec)
    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# Activation rules per execution context
# ---------------------------------------------------------------------------


def train_act_rules(multi_pod: bool = False) -> Dict[str, Optional[object]]:
    """Single-pod: DP over data, TP over model.  Multi-pod: same inside a
    stage (the pod axis is manual inside the pipeline shard_map)."""
    return {
        "batch": "data", "batch_head": "data", "seq": None, "embed": None,
        "heads": "model", "kv_heads": "model", "ff": "model",
        "vocab": "model", "expert": "data", "kv_seq": None,
    }


def prefill_act_rules(multi_pod: bool = False) -> Dict[str, Optional[object]]:
    """Prefill is pure forward: DP over every free axis (pods included); the
    produced KV cache is sequence-sharded over model (decode layout)."""
    return {
        "batch": ("pod", "data") if multi_pod else "data",
        "batch_head": ("pod", "data") if multi_pod else "data",
        "seq": None, "embed": None,
        "heads": "model", "kv_heads": None, "ff": "model",
        "vocab": "model", "expert": "data", "kv_seq": "model",
    }


def decode_act_rules(batch: int, multi_pod: bool = False) -> Dict[str, Optional[object]]:
    """Decode: batch over (pod?, data) + KV-cache *sequence* over model (the
    distributed-decode layout — works for any kv-head count incl. MQA);
    batch=1 long-context shards the cache sequence over every free axis."""
    if batch >= 16:
        return {
            "batch": ("pod", "data") if multi_pod else "data",
            "seq": None, "embed": None,
            "heads": "model", "kv_heads": None, "ff": "model",
            "vocab": "model", "expert": "data",
            "kv_seq": "model",
        }
    # long-context: sequence-shard the cache
    kv = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "batch": None, "batch_head": None, "seq": None, "embed": None,
        "heads": "model", "kv_heads": None, "ff": "model",
        "vocab": "model", "expert": "data",
        "kv_seq": kv,  # kv_heads must stay None: same spec as kv_seq axes
    }


def fit_spec(mesh, spec: P, shape) -> P:
    """Drop partition entries whose mesh-axis product does not divide the
    corresponding dim (e.g. vocab 50280 over 16-way 'model', kv_heads 8 over
    16) — those dims are replicated instead.  jit input shardings require
    exact divisibility; real deployments pad instead (see EXPERIMENTS.md)."""
    ax_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        for a in axes:
            prod = 1
            for kk in kept + [a]:
                prod *= ax_size[kk]
            if shape[i] % prod == 0:
                kept.append(a)
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def fitted_shardings(mesh, spec_tree, struct_tree) -> Any:
    """NamedSharding tree with per-leaf divisibility fitting."""
    return jax.tree.map(
        lambda sp, st: NamedSharding(mesh, fit_spec(mesh, sp, st.shape)),
        spec_tree, struct_tree)


# ---------------------------------------------------------------------------
# IntraOpPlan lowering: planner output -> executable mesh + shard sizes
# ---------------------------------------------------------------------------


def validate_intra_op_plan(plan: IntraOpPlan) -> None:
    """Check the planner's invariants before lowering: ratios are positive,
    one per data-parallel shard, and sum to 1; degrees are positive."""
    if plan.tp < 1 or plan.dp < 1:
        raise ValueError(f"degrees must be >= 1, got tp={plan.tp} dp={plan.dp}")
    if len(plan.shard_ratios) != plan.dp:
        raise ValueError(
            f"{len(plan.shard_ratios)} shard ratios for dp={plan.dp}")
    if any(r <= 0 for r in plan.shard_ratios):
        raise ValueError(f"non-positive shard ratio in {plan.shard_ratios}")
    total = sum(plan.shard_ratios)
    if abs(total - 1.0) > 1e-6:
        raise ValueError(f"shard ratios sum to {total}, expected 1")


def intra_op_mesh_axes(plan: IntraOpPlan) -> Tuple[Tuple[str, int], ...]:
    """Logical mesh layout for one stage: ``(("data", dp), ("model", tp))``.
    Pure (no jax devices needed) — :func:`mesh_from_intra_op` materializes
    it."""
    validate_intra_op_plan(plan)
    return (("data", plan.dp), ("model", plan.tp))


def hierarchical_sync_axes(plan: IntraOpPlan, mesh_n: int
                           ) -> Tuple[Tuple[str, int], ...]:
    """Mesh layout that lowers the *two-level hierarchical* gradient sync
    (``plan.sync_algo == "hierarchical"``) of a stage spanning ``mesh_n``
    nodes: the flat ``("data", dp)`` axis splits into ``("node", mesh_n)``
    x ``("data", dp // mesh_n)`` so the reduce's phases map onto named
    axes — reduce-scatter over ``data`` (intra-node fabric), cross-node
    allreduce over ``node`` (inter-node fabric), allgather over ``data``.
    Requires ``mesh_n`` to divide ``dp`` (it does by construction:
    ``dp = mesh_n * per_node``)."""
    validate_intra_op_plan(plan)
    if mesh_n < 1 or plan.dp % mesh_n != 0:
        raise ValueError(
            f"mesh_n={mesh_n} does not factor dp={plan.dp}")
    return (("node", mesh_n), ("data", plan.dp // mesh_n),
            ("model", plan.tp))


def sync_collective_phases(plan: IntraOpPlan, mesh_n: int
                           ) -> Tuple[Tuple[str, str], ...]:
    """The gradient sync as (collective, mesh axis) phases, matching the
    algorithm the planner priced (``repro.comm.algorithms``):

    - hierarchical (multi-node stage): reduce-scatter over ``data``,
      allreduce over ``node``, allgather over ``data``;
    - anything else (flat ring / rhd / legacy): one allreduce over the flat
      data axis.

    Executors iterate these phases verbatim; the axis names refer to
    :func:`hierarchical_sync_axes` / :func:`intra_op_mesh_axes`."""
    if plan.sync_algo == "hierarchical" and mesh_n > 1:
        return (("reduce_scatter", "data"), ("all_reduce", "node"),
                ("all_gather", "data"))
    return (("all_reduce", "data"),)


def mesh_from_intra_op(plan: IntraOpPlan, devices: Optional[Sequence] = None,
                       *, hierarchy_nodes: Optional[int] = None) -> Mesh:
    """Materialize a stage's ``IntraOpPlan`` as a jax ``Mesh`` with axes
    ``("data", "model")`` of shape ``(dp, tp)``.  ``devices`` defaults to
    ``jax.devices()`` and must supply at least ``plan.n_devices`` entries;
    the degenerate degree=1 plan yields a 1x1 mesh (single-device no-op
    through which every PartitionSpec replicates).

    CONTRACT for uneven plans: ``plan.shard_ratios`` are ordered slowest
    node first (ascending ``SubCluster.node_scales``), and data-shard ``i``
    runs on ``devices[i*tp:(i+1)*tp]`` — so the caller must order
    ``devices`` by ascending node efficiency or the uneven shards land on
    the wrong nodes and execute *slower* than even sharding.

    ``hierarchy_nodes``: materialize the three-axis
    :func:`hierarchical_sync_axes` layout instead (stages whose gradient
    sync lowers to the two-level hierarchy) — same device order, the data
    axis merely split as ``node x data``."""
    axes = hierarchical_sync_axes(plan, hierarchy_nodes) \
        if hierarchy_nodes is not None else intra_op_mesh_axes(plan)
    if devices is None:
        devices = jax.devices()
    need = plan.n_devices
    if len(devices) < need:
        raise ValueError(
            f"plan needs {need} devices (tp={plan.tp} x dp={plan.dp}), "
            f"got {len(devices)}")
    grid = np.asarray(devices[:need], dtype=object).reshape(
        [size for _, size in axes])
    return Mesh(grid, tuple(name for name, _ in axes))


def apportion(total: int, weights: Sequence[float]) -> List[int]:
    """Largest-remainder apportionment of ``total`` integer units across
    ``weights`` (need not be normalized).  Always sums to ``total`` exactly
    — the shared primitive behind :func:`batch_shard_sizes` (samples) and
    ``repro.migrate``'s byte-interval layouts, where exactness is what makes
    plan-to-plan resharding bit-identical."""
    if total < 0:
        raise ValueError("total must be non-negative")
    if not weights or any(w < 0 for w in weights):
        raise ValueError(f"weights must be non-empty and >= 0: {weights}")
    wsum = float(sum(weights))
    if wsum <= 0:
        raise ValueError("weights must sum to > 0")
    quotas = [w / wsum * total for w in weights]
    sizes = [int(q) for q in quotas]
    rema = sorted(range(len(weights)), key=lambda i: quotas[i] - sizes[i],
                  reverse=True)
    for i in rema[: total - sum(sizes)]:
        sizes[i] += 1
    return sizes


def batch_shard_sizes(plan: IntraOpPlan, batch: int) -> List[int]:
    """Integer per-dp-shard batch sizes from the plan's (possibly uneven)
    ratios, by largest-remainder apportionment.  Always sums to ``batch``;
    even ratios reproduce the usual ``batch // dp`` split.  ``batch`` is a
    sample/microbatch count, not bytes."""
    validate_intra_op_plan(plan)
    return apportion(batch, list(plan.shard_ratios))


def cache_pspecs(cache_tree, rules: Dict[str, Optional[object]]) -> Any:
    """KV-cache / SSM-state specs.

    KV leaves: (L..., B, S, KV, D) -> (batch, kv_seq, kv_heads) rules on the
    trailing 4 dims.  SSM state leaves: 's' (L..., B, H, P, N), 'conv'
    (L..., B, K, C)."""
    def one(kp, leaf):
        path = tuple(_key_str(k) for k in kp)
        name = path[-1]
        nd = len(leaf.shape)
        if name in ("s",):
            spec = (rules["batch"], rules["heads"], None, None)
        elif name in ("conv",):
            spec = (rules["batch"], None, rules["ff"])
        else:  # k / v / mem_k / mem_v and grouped variants
            spec = (rules["batch"], rules["kv_seq"], rules["kv_heads"], None)
        pad = nd - len(spec)
        return P(*([None] * pad), *spec)
    return jax.tree_util.tree_map_with_path(one, cache_tree)
