"""Gradient compression for slow (cross-pod) reductions: int8 block
quantization with error feedback.

Beyond-paper distributed-optimization trick (HAPT avoids cross-cluster
collectives entirely; when a deployment *does* reduce gradients across the
DCN — e.g. zamba2's shared block whose parameters live on every stage — 4x
smaller payloads matter).  Error feedback keeps the quantization bias out of
the optimizer: the residual (g - dequant(quant(g))) is added to the next
step's gradient, which provably preserves SGD convergence.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = _pad_len(flat.size)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads, err_fb):
    """Apply error feedback + quantize each leaf.  Returns (payload, new_err).

    payload leaves are (q, scale) pairs — 4x smaller on the wire than f32."""
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    e_leaves = treedef.flatten_up_to(err_fb)
    qs, errs = [], []
    for g, e in zip(g_leaves, e_leaves):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape)
        qs.append((q, s))
        errs.append(corrected - deq)
    payload = jax.tree_util.tree_unflatten(treedef, qs)
    new_err = jax.tree_util.tree_unflatten(treedef, errs)
    return payload, new_err


def decompress_tree(payload, template):
    return jax.tree.map(
        lambda qs, t: dequantize_int8(qs[0], qs[1], t.shape, t.dtype),
        payload, template,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
