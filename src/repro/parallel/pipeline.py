"""SPMD pipeline parallelism over the multi-pod mesh's ``pod`` axis.

The paper's rule 1 confines intra-op parallelism (DP/TP) inside a pod; the
``pod`` axis carries only inter-op (pipeline) traffic — microbatch activation
``collective-permute``s, the TPU-idiomatic equivalent of HAPT's cross-cluster
P2P sends.

Mechanics (collective-permute pipelining):
  - every stage's parameters are stacked along a leading stage dim sharded
    over ``pod``; ``shard_map`` is *manual* over ``pod`` only, with ``data``/
    ``model`` staying auto (GSPMD does DP/TP inside the stage body);
  - a ``lax.scan`` runs ``n_microbatches + S - 1`` slots; each slot the stage
    applies its layers to the activation it holds and ``ppermute``s the
    result to the next stage;
  - the first model layer swaps in the next microbatch's embedded input (a
    per-layer flag, so the mechanism is family-agnostic); the CE loss is
    computed at every stage but masked to the last (head redundancy is S-1/S
    of one matmul — measured in EXPERIMENTS.md);
  - slots are remat'd (``jax.checkpoint``), so live memory = in-flight
    activations, matching the planner's Eq. 18 accounting.

Backward is reverse-mode through the scan: ppermute transposes to the
reverse permute, giving a GPipe-schedule backward.  The H-1F1B warm-up-depth
schedule itself is modeled and proven in ``core/`` (pipesim) and drives the
planner's memory bound; XLA's async collective-permute pairs provide the
overlap on hardware.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_loss_fn(spec, mesh, n_microbatches: int, stage_axis: str = "pod"):
    """Build ``loss(staged, shared, consts, batch) -> (loss, metrics)``.

    ``spec`` is a family staging (see ``parallel/staging.py``) providing
    make_io / stage_fn / head_loss / zero_carry."""
    from repro import compat
    S = spec.n_stages
    n_mb = n_microbatches

    def inner(staged_local, consts_local, shared, io):
        staged1 = jax.tree.map(lambda x: x[0], staged_local)
        consts1 = jax.tree.map(lambda x: x[0], consts_local)
        sidx = jax.lax.axis_index(stage_axis)
        is_last = (sidx == S - 1).astype(jnp.float32)
        carry0 = jax.tree.map(
            lambda x: compat.pcast_varying(x, stage_axis),
            spec.zero_carry(io))
        perm = [(i, i + 1) for i in range(S - 1)]

        def step(carry, t):
            io_t = jax.tree.map(lambda a: a[jnp.clip(t, 0, n_mb - 1)], io)
            carry = spec.stage_fn(staged1, consts1, shared, carry, io_t)
            out_idx = jnp.clip(t - (S - 1), 0, n_mb - 1)
            io_out = jax.tree.map(lambda a: a[out_idx], io)
            ce_sum, ntok, aux = spec.head_loss(shared, carry, io_out)
            valid = jnp.asarray(t >= S - 1, jnp.float32) * is_last
            if S > 1:
                carry = jax.tree.map(
                    lambda x: jax.lax.ppermute(x, stage_axis, perm), carry)
            return carry, (ce_sum * valid, ntok * valid, aux * valid)

        from repro.models.common import scan_unroll
        step = jax.checkpoint(step)
        _, (ce, tok, aux) = jax.lax.scan(step, carry0,
                                         jnp.arange(n_mb + S - 1),
                                         unroll=scan_unroll())
        return (jnp.sum(ce)[None], jnp.sum(tok)[None], jnp.sum(aux)[None])

    smapped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(P(stage_axis), P(stage_axis), P(), P()),
        out_specs=(P(stage_axis), P(stage_axis), P(stage_axis)),
        axis_names={stage_axis})

    def loss_fn(staged, shared, consts, batch):
        io = spec.make_io(shared, batch, n_mb)
        ce_v, tok_v, aux_v = smapped(staged, consts, shared, io)
        tokens = jnp.maximum(jnp.sum(tok_v), 1.0)
        ce = jnp.sum(ce_v) / tokens
        aux = jnp.sum(aux_v) / n_mb
        return ce + aux, {"loss": ce, "aux_loss": aux, "tokens": tokens}

    return loss_fn
