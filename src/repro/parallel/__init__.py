from repro.parallel import sharding
from repro.parallel.pipeline import pipeline_loss_fn
from repro.parallel.staging import build_staging
