"""Per-family pipeline stage decompositions.

``build_staging(cfg, n_stages, params)`` restructures a model's parameter
pytree into (staged, shared, consts):

  staged — every leaf gains a leading ``S`` dim (sharded over ``pod``);
  shared — embed / head / norms / zamba's shared block (replicated over pod);
  consts — non-trainable per-layer flag arrays (first-layer injection,
           identity-padding gates for uneven stage splits).

The *first-layer flag* makes the engine family-agnostic: layer ``l`` computes
``x = f_l * io.h_in + (1 - f_l) * h`` before its block, so only the stage
owning the model's first layer consumes fresh microbatches; everyone else
consumes the ppermute'd carry.  Uneven splits (zamba2's 81 = 13x6+3) are
padded to uniform unit counts with zero gates (identity layers) — the pad
waste is reported by the planner.

Stage divisibility per assigned arch at S=2 pods: minitron 32, deepseek 30,
gemma 18, gemma3 8 groups, qwen3 94, granite 24, mamba2 64, vlm 20 groups,
zamba2 14 padded units — all even.  whisper-medium (0.8B) is deliberately
*not* pipelined: the planner places sub-1B models data-parallel across pods
(see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import hybrid_lm, mamba_lm, moe_lm, transformer, vlm
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    linear, rms_norm, scan_unroll, shard_act, softmax_cross_entropy,
)
from repro.models.moe import moe_block
from repro.models.ssm import ssm_block

Params = Dict[str, Any]


@dataclass
class Staging:
    cfg: ArchConfig
    n_stages: int
    staged: Params
    shared: Params
    consts: Params
    stage_fn: Callable          # (staged1, consts1, shared, carry, io_t) -> carry
    make_io: Callable           # (shared, batch, n_mb) -> io
    head_loss: Callable         # (shared, carry, io_t) -> (ce_sum, ntok, aux)
    zero_carry: Callable        # (io) -> carry


def _with_dtype(mk, sh, b, n, dt):
    io = mk(sh, b, n)
    io["h_in"] = io["h_in"].astype(dt)
    if "img" in io:
        io["img"] = io["img"].astype(dt)
    return io


def _is_struct_tree(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and isinstance(leaves[0], jax.ShapeDtypeStruct)


def _apply_restructure(fn, params):
    """Run the pure reshape/concat restructuring; under ShapeDtypeStructs it
    runs through eval_shape (dry-run: no allocation)."""
    if _is_struct_tree(params):
        return jax.eval_shape(fn, params)
    return fn(params)


def _mix(f, io_h, h):
    f = f.astype(h.dtype)
    return f * io_h.astype(h.dtype) + (1.0 - f) * h


def _reshape_stage(tree, S):
    return jax.tree.map(lambda x: x.reshape(S, x.shape[0] // S, *x.shape[1:]),
                        tree)


def _make_io_lm(cfg: ArchConfig, shared, batch, n_mb, act_dtype=jnp.bfloat16):
    tokens, labels = batch["tokens"], batch["labels"]
    B, T = tokens.shape
    mb = B // n_mb
    h = transformer.embed_tokens(cfg, {"embed": shared["embed"]}, tokens)
    h = h.astype(act_dtype).reshape(n_mb, mb, T, -1)
    h = shard_act(h, (None, "batch", "seq", "embed"))
    io = {"h_in": h, "labels": labels.reshape(n_mb, mb, T)}
    return io


def _head_loss_lm(cfg: ArchConfig, shared, carry, io_t):
    h = jnp.nan_to_num(carry["h"])  # pre-warmup garbage on non-last stages
    logits = transformer.lm_head(cfg, shared, h)
    per_tok, _ = softmax_cross_entropy(logits, io_t["labels"])
    ntok = jnp.asarray(per_tok.size, jnp.float32)
    return jnp.sum(per_tok), ntok, carry.get("aux", jnp.zeros((), jnp.float32))


def _zero_carry_lm(io, with_aux=True):
    c = {"h": jnp.zeros_like(io["h_in"][0])}
    if with_aux:
        c["aux"] = jnp.zeros((), jnp.float32)
    return c


# ---------------------------------------------------------------------------
# dense (uniform + gemma3 local:global pattern)
# ---------------------------------------------------------------------------


def _stage_dense(cfg: ArchConfig, S: int, params: Params) -> Staging:
    use_pallas = False
    ratio = cfg.local_global_ratio
    L = cfg.n_layers
    if ratio:
        gsz = ratio + 1
        G = L // gsz
        first = jnp.zeros((S, G // S, gsz), jnp.float32).at[0, 0, 0].set(1.0)
    else:
        first = jnp.zeros((S, L // S), jnp.float32).at[0, 0].set(1.0)
    consts = {"first": first}

    def restructure(p):
        if ratio:
            blocks = jax.tree.map(
                lambda x: x.reshape(G, gsz, *x.shape[1:]), p["blocks"])
            stg = {"blocks": _reshape_stage(blocks, S)}
        else:
            stg = {"blocks": _reshape_stage(p["blocks"], S)}
        return stg, {k: v for k, v in p.items() if k != "blocks"}

    staged, shared = _apply_restructure(restructure, params)

    def stage_fn(staged1, consts1, shared_, carry, io_t):
        h = carry["h"]
        if ratio:
            def gbody(hh, xs):
                pg, fg = xs
                for i in range(gsz):
                    p = jax.tree.map(lambda x: x[i], pg)
                    hh = _mix(fg[i], io_t["h_in"], hh)
                    w = cfg.sliding_window if i < ratio else 0
                    hh = transformer._block_apply(cfg, p, hh, window=w,
                                                  use_pallas=use_pallas)
                return hh, None
            h, _ = jax.lax.scan(gbody, h,
                                (staged1["blocks"], consts1["first"]),
                                unroll=scan_unroll())
        else:
            def body(hh, xs):
                p, f = xs
                hh = _mix(f, io_t["h_in"], hh)
                hh = transformer._block_apply(cfg, p, hh,
                                              window=cfg.sliding_window,
                                              use_pallas=use_pallas)
                return hh, None
            h, _ = jax.lax.scan(body, h,
                                (staged1["blocks"], consts1["first"]),
                                unroll=scan_unroll())
        return {**carry, "h": h}

    return Staging(cfg, S, staged, shared, consts, stage_fn,
                   lambda sh, b, n: _make_io_lm(cfg, sh, b, n),
                   lambda sh, c, i: _head_loss_lm(cfg, sh, c, i),
                   lambda io: _zero_carry_lm(io, with_aux=False))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _stage_moe(cfg: ArchConfig, S: int, params: Params) -> Staging:
    L = cfg.n_layers
    consts = {"first": jnp.zeros((S, L // S), jnp.float32).at[0, 0].set(1.0)}

    def restructure(p):
        return ({"blocks": _reshape_stage(p["blocks"], S)},
                {k: v for k, v in p.items() if k != "blocks"})

    staged, shared = _apply_restructure(restructure, params)

    def stage_fn(staged1, consts1, shared_, carry, io_t):
        def body(c, xs):
            hh, aux = c
            p, f = xs
            hh = _mix(f, io_t["h_in"], hh)
            hh, a = moe_lm._block_apply(cfg, p, hh, use_pallas=False)
            return (hh, aux + a), None
        (h, aux), _ = jax.lax.scan(
            body, (carry["h"], carry["aux"]),
            (staged1["blocks"], consts1["first"]), unroll=scan_unroll())
        return {"h": h, "aux": aux}

    return Staging(cfg, S, staged, shared, consts, stage_fn,
                   lambda sh, b, n: _make_io_lm(cfg, sh, b, n),
                   lambda sh, c, i: _head_loss_lm(cfg, sh, c, i),
                   _zero_carry_lm)


# ---------------------------------------------------------------------------
# SSM (mamba2)
# ---------------------------------------------------------------------------


def _stage_ssm(cfg: ArchConfig, S: int, params: Params) -> Staging:
    L = cfg.n_layers
    consts = {"first": jnp.zeros((S, L // S), jnp.float32).at[0, 0].set(1.0)}

    def restructure(p):
        return ({"blocks": _reshape_stage(p["blocks"], S)},
                {k: v for k, v in p.items() if k != "blocks"})

    staged, shared = _apply_restructure(restructure, params)

    def stage_fn(staged1, consts1, shared_, carry, io_t):
        def body(hh, xs):
            p, f = xs
            hh = _mix(f, io_t["h_in"], hh)
            return mamba_lm._block_apply(cfg, p, hh, use_pallas=False), None
        h, _ = jax.lax.scan(
            body, carry["h"],
            (staged1["blocks"], consts1["first"]), unroll=scan_unroll())
        return {**carry, "h": h}

    return Staging(cfg, S, staged, shared, consts, stage_fn,
                   lambda sh, b, n: _make_io_lm(cfg, sh, b, n),
                   lambda sh, c, i: _head_loss_lm(cfg, sh, c, i),
                   lambda io: _zero_carry_lm(io, with_aux=False))


# ---------------------------------------------------------------------------
# hybrid (zamba2): units of (k SSM layers + shared-block application)
# ---------------------------------------------------------------------------


def _stage_hybrid(cfg: ArchConfig, S: int, params: Params) -> Staging:
    k = cfg.shared_attn_every
    n_apps = cfg.n_layers // k
    n_tail = cfg.n_layers - n_apps * k
    U = n_apps + (1 if n_tail else 0)        # padded unit count
    assert U % S == 0, f"zamba2 units {U} not divisible by {S} stages"

    def pad_units(x_groups, x_tail):
        # x_groups: (n_apps, k, ...); x_tail: (n_tail, ...)
        flat = x_groups.reshape(n_apps * k, *x_groups.shape[2:])
        if n_tail:
            pad = jnp.zeros((k - n_tail, *x_tail.shape[1:]), x_tail.dtype)
            flat = jnp.concatenate([flat, x_tail, pad], axis=0)
        return flat.reshape(U, k, *flat.shape[1:])

    def restructure(p):
        units = jax.tree.map(pad_units, p["groups"], p["tail"])
        a_in = jnp.concatenate(
            [p["adapt_in"],
             jnp.zeros((U - n_apps, *p["adapt_in"].shape[1:]),
                       p["adapt_in"].dtype)], axis=0)
        a_out = jnp.concatenate(
            [p["adapt_out"],
             jnp.zeros((U - n_apps, *p["adapt_out"].shape[1:]),
                       p["adapt_out"].dtype)], axis=0)
        stg = {"units": _reshape_stage(units, S),
               "adapt_in": _reshape_stage(a_in, S),
               "adapt_out": _reshape_stage(a_out, S)}
        shr = {kk: v for kk, v in p.items()
               if kk in ("embed", "final_norm", "lm_head", "shared")}
        return stg, shr

    staged, shared = _apply_restructure(restructure, params)

    ssm_gate = jnp.ones((U, k), jnp.float32)
    app_gate = jnp.ones((U,), jnp.float32)
    if n_tail:
        ssm_gate = ssm_gate.at[U - 1, n_tail:].set(0.0)
        app_gate = app_gate.at[U - 1].set(0.0)
    first = jnp.zeros((U, k), jnp.float32).at[0, 0].set(1.0)
    consts = {"ssm_gate": ssm_gate.reshape(S, U // S, k),
              "app_gate": app_gate.reshape(S, U // S),
              "first": first.reshape(S, U // S, k)}

    def stage_fn(staged1, consts1, shared_, carry, io_t):
        def unit_body(hh, xs):
            pu, ai, ao, sg, ag, fg = xs

            def lbody(c, ys):
                p, g, f = ys
                c = _mix(f, io_t["h_in"], c)
                delta = mamba_lm._block_apply(cfg, p, c, use_pallas=False) - c
                return c + g * delta, None
            hh, _ = jax.lax.scan(lbody, hh, (pu, sg, fg),
                                 unroll=scan_unroll())
            # shared transformer block through adapters (weights shared
            # across all applications and stages — replicated params)
            x = linear(hh, ai)
            y = attn.self_attention(
                shared_["shared"]["attn"],
                rms_norm(x, shared_["shared"]["ln1"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True)
            x = x + y
            x = x + mlp_mod.mlp(shared_["shared"]["mlp"],
                                rms_norm(x, shared_["shared"]["ln2"],
                                         cfg.norm_eps), cfg.activation)
            hh = hh + ag * linear(x, ao)
            return hh, None

        h, _ = jax.lax.scan(
            unit_body, carry["h"],
            (staged1["units"], staged1["adapt_in"], staged1["adapt_out"],
             consts1["ssm_gate"], consts1["app_gate"], consts1["first"]),
            unroll=scan_unroll())
        return {**carry, "h": h}

    return Staging(cfg, S, staged, shared, consts, stage_fn,
                   lambda sh, b, n: _make_io_lm(cfg, sh, b, n),
                   lambda sh, c, i: _head_loss_lm(cfg, sh, c, i),
                   lambda io: _zero_carry_lm(io, with_aux=False))


# ---------------------------------------------------------------------------
# VLM (llama-3.2-vision): groups of (n self blocks + 1 cross block)
# ---------------------------------------------------------------------------


def _stage_vlm(cfg: ArchConfig, S: int, params: Params) -> Staging:
    G, n_self = vlm._group_dims(cfg)
    assert G % S == 0

    def restructure(p):
        return ({"self_blocks": _reshape_stage(p["self_blocks"], S),
                 "cross_blocks": _reshape_stage(p["cross_blocks"], S)},
                {k: v for k, v in p.items()
                 if k in ("embed", "final_norm", "lm_head")})

    staged, shared = _apply_restructure(restructure, params)
    consts = {"first": jnp.zeros((S, G // S, n_self), jnp.float32)
              .at[0, 0, 0].set(1.0)}

    def make_io(shared_, batch, n_mb):
        io = _make_io_lm(cfg, shared_, batch, n_mb)
        B = batch["tokens"].shape[0]
        mb = B // n_mb
        img = batch["image_embeds"].astype(io["h_in"].dtype)
        io["img"] = img.reshape(n_mb, mb, *img.shape[1:])
        return io

    def stage_fn(staged1, consts1, shared_, carry, io_t):
        def gbody(hh, xs):
            pg_self, pg_cross, fg = xs

            def sbody(c, ys):
                p, f = ys
                c = _mix(f, io_t["h_in"], c)
                return transformer._block_apply(cfg, p, c, window=0,
                                                use_pallas=False), None
            hh, _ = jax.lax.scan(sbody, hh, (pg_self, fg),
                                 unroll=scan_unroll())
            hh = vlm._cross_apply(cfg, pg_cross, hh, io_t["img"],
                                  use_pallas=False)
            return hh, None
        h, _ = jax.lax.scan(
            gbody, carry["h"],
            (staged1["self_blocks"], staged1["cross_blocks"],
             consts1["first"]), unroll=scan_unroll())
        return {**carry, "h": h}

    return Staging(cfg, S, staged, shared, consts, stage_fn, make_io,
                   lambda sh, c, i: _head_loss_lm(cfg, sh, c, i),
                   lambda io: _zero_carry_lm(io, with_aux=False))


# ---------------------------------------------------------------------------


def build_staging(cfg: ArchConfig, n_stages: int, params: Params,
                  act_dtype=jnp.bfloat16) -> Staging:
    fam = cfg.family
    if fam == "dense":
        st = _stage_dense(cfg, n_stages, params)
    elif fam == "moe":
        st = _stage_moe(cfg, n_stages, params)
    elif fam == "ssm":
        st = _stage_ssm(cfg, n_stages, params)
    elif fam == "hybrid":
        st = _stage_hybrid(cfg, n_stages, params)
    elif fam == "vlm":
        st = _stage_vlm(cfg, n_stages, params)
    else:
        st = None
    if st is not None:
        mk = st.make_io
        st.make_io = lambda sh, b, n: _with_dtype(mk, sh, b, n, act_dtype)
        return st
    raise ValueError(
        f"family {fam!r} is not pipelined (audio trains data-parallel across "
        "pods — see DESIGN.md)")
