"""Heterogeneity-aware inference planning (DESIGN.md §7).

HARP's core move — place work on the pool whose compute/memory/network
profile suits it — applied to serving: prefill is compute-bound (suits the
high-FLOPs sub-clusters), decode is memory-bandwidth/KV-capacity-bound
(suits the memory-rich stragglers).  The subsystem mirrors the training
stack's staging:

- :mod:`repro.serving.workload`  — typed request traces (the input);
- :mod:`repro.serving.kvplan`    — the KV-cache capacity bound (Eq. 18's
  serving analog) with paged-block accounting;
- :mod:`repro.serving.placement` — disaggregated prefill/decode placement
  search over the fleet's pools, KV handoff priced through
  :mod:`repro.comm`'s tiered links;
- :mod:`repro.serving.batching`  — event-driven continuous-batching
  simulator (admission control, prefill chunking, decode step batching);
- :mod:`repro.serving.objective` — latency-SLO and max-throughput scoring.

None of these import jax: like the planner stack, serving plans are
searchable on a CPU-only box and ship as JSON (the ``serve`` section of the
schema-v4 Plan artifact).
"""
from repro.serving.batching import ServeSimResult, simulate_trace
from repro.serving.kvplan import (
    KVBound, blocks_for_seq, decode_capacity, kv_bytes_per_token,
    state_bytes_per_seq,
)
from repro.serving.objective import percentile, score
from repro.serving.placement import (
    PoolSpec, ServePlan, ServingConfig, colocated_plan, search_placement,
)
from repro.serving.workload import (
    Request, ServeTrace, poisson_trace, scripted_trace,
)

__all__ = [
    "KVBound", "PoolSpec", "Request", "ServePlan", "ServeSimResult",
    "ServeTrace", "ServingConfig", "blocks_for_seq", "colocated_plan",
    "decode_capacity", "kv_bytes_per_token", "percentile", "poisson_trace",
    "score", "scripted_trace", "search_placement", "simulate_trace",
    "state_bytes_per_seq",
]
