"""Typed serving request traces.

The serving analog of :mod:`repro.runtime.events`: a trace is an immutable,
sorted tuple of frozen :class:`Request` values, produced either by a seeded
generator (:func:`poisson_trace` — Poisson arrivals, log-normal
prompt/output lengths, deterministic per seed) or a *scripted* process
(:func:`scripted_trace` — evenly spaced arrivals with fixed lengths, the
regression-fixture flavor).

Recorded traces replay at a different load via the time-remapping idiom
(:meth:`ServeTrace.remapped`): inter-arrival gaps are rescaled so the same
request population — same lengths, same order — arrives at a target QPS.
That is how one recorded workload sweeps a QPS axis without resampling.

No jax imports; traces are JSON round-trippable (they ride inside the
schema-v4 plan artifact's provenance and the benchmark output).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Dict, Sequence, Tuple


@dataclass(frozen=True)
class Request:
    """One inference request: arrives at ``arrival_s``, carries a prompt of
    ``prompt_tokens`` and wants ``output_tokens`` decoded."""
    rid: int
    arrival_s: float
    prompt_tokens: int
    output_tokens: int

    def describe(self) -> str:
        return (f"req{self.rid}@{self.arrival_s:.3f}s "
                f"({self.prompt_tokens}+{self.output_tokens} tok)")


@dataclass(frozen=True)
class ServeTrace:
    """Requests sorted by arrival time."""
    requests: Tuple[Request, ...]

    def __post_init__(self):
        arr = [r.arrival_s for r in self.requests]
        if arr != sorted(arr):
            object.__setattr__(
                self, "requests",
                tuple(sorted(self.requests, key=lambda r: (r.arrival_s,
                                                           r.rid))))

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def duration_s(self) -> float:
        return self.requests[-1].arrival_s if self.requests else 0.0

    @property
    def qps(self) -> float:
        """Mean arrival rate over the trace span."""
        if self.n_requests < 2 or self.duration_s <= 0:
            return float(self.n_requests)
        return (self.n_requests - 1) / self.duration_s

    @property
    def total_prompt_tokens(self) -> int:
        return sum(r.prompt_tokens for r in self.requests)

    @property
    def total_output_tokens(self) -> int:
        return sum(r.output_tokens for r in self.requests)

    # -- replay idioms -------------------------------------------------------

    def remapped(self, qps: float) -> "ServeTrace":
        """Time-remapped replay: the same requests (lengths, order) with
        inter-arrival gaps rescaled to a mean rate of ``qps``."""
        if qps <= 0:
            raise ValueError(f"target qps must be positive, got {qps}")
        cur = self.qps
        if cur <= 0 or self.n_requests < 2:
            return self
        scale = cur / qps
        return ServeTrace(tuple(
            Request(r.rid, r.arrival_s * scale, r.prompt_tokens,
                    r.output_tokens) for r in self.requests))

    def take(self, n: int) -> "ServeTrace":
        """Prefix of the trace (placement-search sampling)."""
        return self if n <= 0 or n >= self.n_requests \
            else ServeTrace(self.requests[:n])

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {"requests": [[r.rid, r.arrival_s, r.prompt_tokens,
                              r.output_tokens] for r in self.requests]}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ServeTrace":
        return ServeTrace(tuple(Request(int(a), float(b), int(c), int(e))
                                for a, b, c, e in d["requests"]))

    def describe(self) -> str:
        if not self.requests:
            return "(empty trace)"
        return (f"{self.n_requests} requests over {self.duration_s:.2f}s "
                f"({self.qps:.1f} qps), "
                f"{self.total_prompt_tokens} prompt + "
                f"{self.total_output_tokens} output tokens")


def _lognormal_tokens(rng: random.Random, mean: int, lo: int,
                      sigma: float = 0.6) -> int:
    """Integer token count ~ LogNormal with the requested mean, clamped to
    [lo, 8*mean] (an unclamped tail occasionally draws a prompt longer than
    any pool's KV capacity, which only tests rejection paths)."""
    mu = math.log(mean) - sigma * sigma / 2.0
    return max(lo, min(8 * mean, int(round(rng.lognormvariate(mu, sigma)))))


def poisson_trace(qps: float, duration_s: float, *, seed: int = 0,
                  prompt_mean: int = 512, output_mean: int = 64,
                  prompt_min: int = 16, output_min: int = 4) -> ServeTrace:
    """Seeded Poisson arrival process with log-normal length marginals.
    Deterministic per (seed, qps, duration, means)."""
    if qps <= 0 or duration_s <= 0:
        raise ValueError("poisson_trace needs positive qps and duration_s")
    rng = random.Random(seed)
    reqs = []
    t = 0.0
    while True:
        t += rng.expovariate(qps)
        if t >= duration_s:
            break
        reqs.append(Request(
            rid=len(reqs), arrival_s=t,
            prompt_tokens=_lognormal_tokens(rng, prompt_mean, prompt_min),
            output_tokens=_lognormal_tokens(rng, output_mean, output_min)))
    return ServeTrace(tuple(reqs))


def scripted_trace(qps: float, n_requests: int, *, prompt_tokens: int = 512,
                   output_tokens: int = 64) -> ServeTrace:
    """Deterministic fixture: ``n_requests`` evenly spaced at rate ``qps``,
    all with identical lengths (golden tests, benchmark floors)."""
    if qps <= 0 or n_requests <= 0:
        raise ValueError("scripted_trace needs positive qps and n_requests")
    gap = 1.0 / qps
    return ServeTrace(tuple(
        Request(rid=i, arrival_s=i * gap, prompt_tokens=prompt_tokens,
                output_tokens=output_tokens) for i in range(n_requests)))
