"""Serving objectives: latency-SLO and max-throughput scoring.

The placement search minimizes :func:`score` over candidate plans, under
the objective named in ``ServingConfig.objective``:

- ``"slo"`` — meet p99 TTFT (time-to-first-token) and p99 TPOT
  (time-per-output-token) targets at the offered QPS.  The score is a
  lexicographic penalty: rejected requests dominate, then relative SLO
  excess, then raw p99 TTFT as the tiebreak among plans that meet the SLO
  — so among feasible plans the search still prefers snappier ones.
- ``"throughput"`` — maximize goodput (output tokens/s of requests that
  met both SLOs); rejections still count against the plan through the
  goodput they forfeit.

Deterministic, numpy-free percentile (linear interpolation, the numpy
default) so scores are bit-stable across platforms.
"""
from __future__ import annotations

import math
from typing import Sequence

OBJECTIVES = ("slo", "throughput")


def percentile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default method), 0 on an
    empty sample set."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = math.floor(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


def score(result, objective: str, *, slo_ttft_s: float,
          slo_tpot_s: float) -> float:
    """Lower is better.  ``result`` is a
    :class:`repro.serving.batching.ServeSimResult`."""
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown serving objective {objective!r}; one of {OBJECTIVES}")
    n = result.n_completed + result.n_rejected
    rej_frac = result.n_rejected / n if n else 0.0
    if objective == "throughput":
        return rej_frac * 1e12 - result.goodput_tokens_per_s
    # "slo": penalty units are chosen so each tier dominates the next —
    # rejections >> SLO violation >> raw latency
    excess = max(0.0, result.p99_ttft_s / slo_ttft_s - 1.0) \
        + max(0.0, result.p99_tpot_s / slo_tpot_s - 1.0)
    return rej_frac * 1e6 + excess * 1e3 + result.p99_ttft_s


def better(a: float, b: float) -> bool:
    """Is score ``a`` strictly better than ``b``?"""
    return a < b
