"""Disaggregated prefill/decode placement search over the fleet's pools.

Each sub-cluster of the :class:`HeteroCluster` is a candidate *pool*
holding a full replica of the model (TP/DP inside the pool, no pipeline —
serving replicas are latency-bound, not capacity-bound like training).  A
placement assigns every pool a role:

- ``prefill`` — runs prompt prefill only (compute-bound: suits the
  high-FLOPs sub-clusters);
- ``decode``  — runs token decode only (HBM-bandwidth/KV-capacity-bound:
  suits the memory-rich stragglers);
- ``mixed``   — both, interleaved (the colocated baseline's role, with the
  prefill-decode interference that implies);
- ``off``     — not used (e.g. the weights don't fit).

The search enumerates role assignments, prices each pool with the training
stack's machinery — prefill chunk time via ``core.costmodel.stage_cost``
through the *profiler's cost-cache key recipe* (entries are shared with
training planner runs on the same fleet), decode step time from an HBM/FLOPs
roofline, KV capacity from :mod:`repro.serving.kvplan` — prices the
prefill→decode KV handoff through :mod:`repro.comm`'s tiered links, then
simulates each candidate on a sample of the trace
(:mod:`repro.serving.batching`) and keeps the best under the configured
objective.  The colocated-uniform baseline (all pools ``mixed``,
round-robin routing) is always simulated for comparison and recorded on
``ServePlan.baseline``.

No jax imports: serving plans are searchable on a CPU-only planning box
and ship as the ``serve`` section of the schema-v4 Plan artifact.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.comm.selector import CommModel
from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster
from repro.core.costmodel import CostModelConfig, Submesh, stage_cost
from repro.core.layering import Layer, build_layers, layer_class_sequence
from repro.core.opgraph import build_op_sequence
from repro.serving import kvplan
from repro.serving.objective import OBJECTIVES, better, score
from repro.serving.workload import ServeTrace, poisson_trace

SERVE_SCHEMA_VERSION = 1

ROLES = ("prefill", "decode", "mixed", "off")

# process-wide stage-cost cache for serving searches (callers may pass the
# elastic runtime's cache instead; keys follow the profiler's recipe, so
# entries interoperate)
_COST_CACHE: Dict = {}


@dataclass
class ServingConfig:
    """Everything the serving planner reads (JSON-native scalars only —
    rides inside :class:`~repro.api.config.HarpConfig`).

    Workload: ``qps``/``duration_s``/``seed`` parameterize the default
    Poisson trace; ``prompt_mean``/``output_mean`` its length marginals.
    Objective: ``"slo"`` (meet p99 TTFT/TPOT targets, see
    :mod:`repro.serving.objective`) or ``"throughput"`` (max goodput).
    KV: cache dtype width, paged-block granularity, memory headroom.
    Batching: prefill chunk tokens, per-pool admission queue bound,
    decode-step dispatch overhead, and the decode MFU derate (decode GEMVs
    reach a fraction of ``base_mfu``).  ``search_sample`` caps the requests
    simulated per placement candidate during the search."""
    qps: float = 32.0
    duration_s: float = 2.0
    seed: int = 0
    prompt_mean: int = 512
    output_mean: int = 64
    objective: str = "slo"
    slo_ttft_s: float = 0.2
    slo_tpot_s: float = 0.02
    kv_dtype_bytes: float = 2.0
    block_tokens: int = 16
    prefill_chunk: int = 256
    max_queue: int = 128
    mem_headroom: float = 0.9
    decode_mfu: float = 0.6
    step_overhead_s: float = 2e-4
    search_sample: int = 512

    def validate_errors(self) -> List[str]:
        errs = []
        if self.qps <= 0:
            errs.append(f"serving.qps must be positive, got {self.qps}")
        if self.duration_s <= 0:
            errs.append(f"serving.duration_s must be positive, "
                        f"got {self.duration_s}")
        if self.objective not in OBJECTIVES:
            errs.append(f"unknown serving.objective {self.objective!r}; "
                        f"one of {OBJECTIVES}")
        if self.prompt_mean <= 0 or self.output_mean <= 0:
            errs.append("serving prompt_mean/output_mean must be positive")
        if self.block_tokens <= 0:
            errs.append(f"serving.block_tokens must be positive, "
                        f"got {self.block_tokens}")
        if self.prefill_chunk <= 0:
            errs.append(f"serving.prefill_chunk must be positive, "
                        f"got {self.prefill_chunk}")
        if not 0.0 < self.mem_headroom <= 1.0:
            errs.append(f"serving.mem_headroom must be in (0, 1], "
                        f"got {self.mem_headroom}")
        if self.slo_ttft_s <= 0 or self.slo_tpot_s <= 0:
            errs.append("serving SLO targets must be positive")
        return errs


@dataclass(frozen=True)
class PoolSpec:
    """One priced pool: role + capacity + the three rates the batching
    simulator reads (chunk prefill time, aggregate HBM bandwidth, aggregate
    decode FLOPs)."""
    name: str
    cluster_idx: int
    role: str                    # 'prefill' | 'decode' | 'mixed'
    n_devices: int
    weights_bytes: float
    block_bytes: float
    blocks_capacity: int
    prefill_chunk_s: float       # seconds per prefill chunk (full pool)
    hbm_bytes_per_s: float       # aggregate effective HBM bandwidth
    decode_flops_per_s: float    # aggregate effective decode FLOP/s

    @property
    def can_prefill(self) -> bool:
        return self.role in ("prefill", "mixed")

    @property
    def can_decode(self) -> bool:
        return self.role in ("decode", "mixed")


@dataclass
class ServePlan:
    """The serving artifact: priced pools + handoff links + the constants
    the simulator needs, JSON round-trippable (``serve`` section of the
    schema-v4 Plan)."""
    arch: str
    objective: str
    routing: str                       # 'least_loaded' | 'uniform'
    prefill_chunk: int                 # tokens per prefill chunk
    block_tokens: int
    kv_bytes_per_token: float
    state_bytes_per_seq: float
    flops_per_token: float             # model forward FLOPs per token
    step_overhead_s: float
    max_queue: int
    slo_ttft_s: float
    slo_tpot_s: float
    pools: List[PoolSpec]
    handoff_bw: Dict[str, float] = field(default_factory=dict)
    handoff_latency: Dict[str, float] = field(default_factory=dict)
    predicted: Dict[str, Any] = field(default_factory=dict)
    baseline: Dict[str, Any] = field(default_factory=dict)
    version: int = SERVE_SCHEMA_VERSION

    # -- handoff pricing -----------------------------------------------------

    def handoff_seconds(self, src: int, dst: int, nbytes: float) -> float:
        """KV-cache shipping time from pool ``src`` to pool ``dst`` over the
        priced link (0 when prefill and decode share the pool)."""
        if src == dst:
            return 0.0
        key = f"{src}->{dst}"
        return nbytes / self.handoff_bw[key] + self.handoff_latency[key]

    def seq_blocks(self, seq_tokens: int) -> int:
        """Paged-block reservation for ``seq_tokens`` of context (mirrors
        :func:`repro.serving.kvplan.blocks_for_seq` using the plan's frozen
        constants — the artifact must not re-derive from the arch)."""
        import math
        if self.kv_bytes_per_token <= 0:
            return 1
        kv_blocks = math.ceil(seq_tokens / self.block_tokens)
        if self.state_bytes_per_seq <= 0:
            return kv_blocks
        bb = self.block_tokens * self.kv_bytes_per_token
        return kv_blocks + math.ceil(self.state_bytes_per_seq / bb)

    def seq_kv_bytes(self, seq_tokens: int) -> float:
        return seq_tokens * self.kv_bytes_per_token + self.state_bytes_per_seq

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": self.version,
            "arch": self.arch,
            "objective": self.objective,
            "routing": self.routing,
            "prefill_chunk": self.prefill_chunk,
            "block_tokens": self.block_tokens,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "state_bytes_per_seq": self.state_bytes_per_seq,
            "flops_per_token": self.flops_per_token,
            "step_overhead_s": self.step_overhead_s,
            "max_queue": self.max_queue,
            "slo_ttft_s": self.slo_ttft_s,
            "slo_tpot_s": self.slo_tpot_s,
            "pools": [dataclasses.asdict(p) for p in self.pools],
            "handoff_bw": dict(self.handoff_bw),
            "handoff_latency": dict(self.handoff_latency),
            "predicted": self.predicted,
            "baseline": self.baseline,
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ServePlan":
        d = dict(d)
        version = d.pop("version", SERVE_SCHEMA_VERSION)
        pools = [PoolSpec(**p) for p in d.pop("pools")]
        return ServePlan(pools=pools, version=version, **d)

    def describe(self) -> str:
        lines = [f"ServePlan[{self.arch}] objective={self.objective} "
                 f"routing={self.routing}"]
        for i, p in enumerate(self.pools):
            extra = ""
            if p.can_decode:
                extra = f", {p.blocks_capacity} KV blocks"
            lines.append(
                f"  pool{i} [{p.name}] role={p.role}: "
                f"{p.n_devices} dev, prefill chunk "
                f"{p.prefill_chunk_s * 1e3:.2f} ms{extra}")
        pred = self.predicted
        if pred:
            lines.append(
                f"  predicted: p99 TTFT {pred.get('p99_ttft_s', 0) * 1e3:.1f}"
                f" ms, p99 TPOT {pred.get('p99_tpot_s', 0) * 1e3:.2f} ms, "
                f"goodput {pred.get('goodput_tokens_per_s', 0):,.0f} tok/s")
        base = self.baseline
        if base:
            lines.append(
                f"  colocated-uniform baseline: p99 TTFT "
                f"{base.get('p99_ttft_s', 0) * 1e3:.1f} ms, goodput "
                f"{base.get('goodput_tokens_per_s', 0):,.0f} tok/s")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Pool pricing
# ---------------------------------------------------------------------------


def serving_layers(arch_cfg: ArchConfig, scfg: ServingConfig,
                   granularity: int = 0) -> List[Layer]:
    """The planner IR at the workload's representative context length
    (prompt + output): attention cost must be priced at serving context,
    not the training seq_len."""
    ctx = max(1, scfg.prompt_mean + scfg.output_mean)
    ops = build_op_sequence(arch_cfg, seq_len=ctx)
    # coarse layering: pool pricing only reads stage *sums*, so a small
    # target keeps the cost-cache key short
    return build_layers(ops, granularity or 8)


def _price_pool(arch_cfg: ArchConfig, cluster: HeteroCluster, ci: int,
                role: str, layers: Sequence[Layer], scfg: ServingConfig,
                cost_cfg: CostModelConfig, cache: Dict,
                comm: Optional[CommModel]) -> Optional[PoolSpec]:
    """Price one sub-cluster as a serving pool, or None when the weights
    don't fit under the headroom (the pool is serving-infeasible)."""
    sub = cluster.subclusters[ci]
    weights = sum(l.param_bytes for l in layers)
    if weights > scfg.mem_headroom * sub.n_devices * sub.device.mem_bytes:
        return None
    bound = kvplan.decode_capacity(
        arch_cfg, sub, weights_bytes=weights,
        block_tokens=scfg.block_tokens, dtype_bytes=scfg.kv_dtype_bytes,
        mem_headroom=scfg.mem_headroom)
    mesh = Submesh(ci, sub.n_nodes, sub.devices_per_node)
    # the profiler's cost-cache key recipe (ZeroRedundantProfiler._cell_costs
    # base_key + tp=None): serving searches and training planner runs on the
    # same fleet share entries for matching (layers, mesh, chunk) cells
    key = (layer_class_sequence(layers, 0, len(layers)),
           sub.device, sub.node_efficiencies,
           sub.intra_node_bw, sub.inter_node_bw,
           mesh.n, mesh.m, scfg.prefill_chunk, cost_cfg, 0,
           None if comm is None else comm.sub_fingerprint(ci), None)
    cost = cache.get(key)
    if cost is None:
        cost = stage_cost(layers, sub, mesh, scfg.prefill_chunk, cost_cfg,
                          comm=comm)
        cache[key] = cost
    # decode roofline inputs: aggregate HBM and derated FLOPs, scaled by the
    # calibrated efficiency and the per-node mix (mean — decode DP shards
    # can be sized unevenly just like training's shard_ratios)
    scales = sub.node_scales()
    mean_scale = sum(scales) / len(scales)
    eff = sub.device.efficiency * mean_scale
    return PoolSpec(
        name=sub.name, cluster_idx=ci, role=role,
        n_devices=sub.n_devices,
        weights_bytes=weights,
        block_bytes=bound.block_bytes,
        blocks_capacity=bound.blocks_capacity,
        prefill_chunk_s=cost.t_f,
        hbm_bytes_per_s=sub.n_devices * sub.device.hbm_bw * eff,
        decode_flops_per_s=sub.n_devices * sub.device.peak_flops
        * sub.device.base_mfu * scfg.decode_mfu * eff)


def _handoff_tables(pools: Sequence[PoolSpec], comm: CommModel
                    ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Per ordered pool pair: the physical link a KV handoff rides (the
    source's inter-node fabric inside a sub-cluster, the shared WAN across
    — ``comm.topology.p2p_link``) with its latency."""
    bw: Dict[str, float] = {}
    lat: Dict[str, float] = {}
    for i, src in enumerate(pools):
        for j, dst in enumerate(pools):
            if i == j:
                continue
            link = comm.topology.p2p_link(src.cluster_idx, dst.cluster_idx)
            key = f"{i}->{j}"
            bw[key] = link.bandwidth
            lat[key] = link.latency
    return bw, lat


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


def _assemble(arch_cfg: ArchConfig, roles: Sequence[str], priced: Dict,
              comm: CommModel, layers: Sequence[Layer],
              scfg: ServingConfig, routing: str) -> Optional[ServePlan]:
    pools = []
    for ci, role in enumerate(roles):
        if role == "off":
            continue
        spec = priced.get(ci)
        if spec is None:
            return None                 # weights don't fit on an used pool
        pools.append(dataclasses.replace(spec, role=role))
    if not any(p.can_prefill for p in pools) \
            or not any(p.can_decode for p in pools):
        return None
    # every decode pool must hold at least one worst-case sequence
    worst = scfg.prompt_mean + scfg.output_mean
    plan_blocks = None
    bw, lat = _handoff_tables(pools, comm)
    plan = ServePlan(
        arch=arch_cfg.arch_id, objective=scfg.objective, routing=routing,
        prefill_chunk=scfg.prefill_chunk, block_tokens=scfg.block_tokens,
        kv_bytes_per_token=kvplan.kv_bytes_per_token(
            arch_cfg, scfg.kv_dtype_bytes),
        state_bytes_per_seq=kvplan.state_bytes_per_seq(
            arch_cfg, scfg.kv_dtype_bytes),
        flops_per_token=sum(l.flops_per_token for l in layers),
        step_overhead_s=scfg.step_overhead_s, max_queue=scfg.max_queue,
        slo_ttft_s=scfg.slo_ttft_s, slo_tpot_s=scfg.slo_tpot_s,
        pools=pools, handoff_bw=bw, handoff_latency=lat)
    plan_blocks = plan.seq_blocks(worst)
    if any(p.can_decode and p.blocks_capacity < plan_blocks for p in pools):
        return None
    return plan


def colocated_plan(arch_cfg: ArchConfig, cluster: HeteroCluster,
                   scfg: Optional[ServingConfig] = None, *,
                   comm: Optional[CommModel] = None,
                   layers: Optional[Sequence[Layer]] = None,
                   cost_cache: Optional[Dict] = None) -> ServePlan:
    """The no-planning baseline: every feasible pool serves both phases
    (``mixed``) and prefill routing is *uniform* round-robin — blind to the
    pools' heterogeneous rates, exactly what a placement-unaware deployment
    does."""
    scfg = scfg or ServingConfig()
    comm = comm or CommModel(cluster)
    layers = list(layers) if layers is not None \
        else serving_layers(arch_cfg, scfg)
    cache = _COST_CACHE if cost_cache is None else cost_cache
    cost_cfg = CostModelConfig()
    priced = {ci: _price_pool(arch_cfg, cluster, ci, "mixed", layers, scfg,
                              cost_cfg, cache, comm)
              for ci in range(len(cluster.subclusters))}
    roles = ["mixed" if priced[ci] is not None else "off"
             for ci in range(len(cluster.subclusters))]
    plan = _assemble(arch_cfg, roles, priced, comm, layers, scfg,
                     routing="uniform")
    if plan is None:
        raise ValueError(
            f"no feasible colocated serving placement for "
            f"{arch_cfg.arch_id} on {cluster.describe()} (weights or one "
            f"worst-case sequence exceed every pool's memory)")
    return plan


def search_placement(arch_cfg: ArchConfig, cluster: HeteroCluster,
                     scfg: Optional[ServingConfig] = None, *,
                     trace: Optional[ServeTrace] = None,
                     comm: Optional[CommModel] = None,
                     layers: Optional[Sequence[Layer]] = None,
                     cost_cache: Optional[Dict] = None,
                     verbose: bool = False) -> ServePlan:
    """Enumerate role assignments, simulate each on a trace sample, keep the
    best under ``scfg.objective``.  The returned plan carries its predicted
    metrics and the colocated-uniform baseline's, both measured on the same
    sample (equal offered QPS)."""
    from repro.serving.batching import simulate_trace

    scfg = scfg or ServingConfig()
    errs = scfg.validate_errors()
    if errs:
        raise ValueError("invalid ServingConfig: " + "; ".join(errs))
    comm = comm or CommModel(cluster)
    layers = list(layers) if layers is not None \
        else serving_layers(arch_cfg, scfg)
    cache = _COST_CACHE if cost_cache is None else cost_cache
    cost_cfg = CostModelConfig()
    if trace is None:
        trace = poisson_trace(scfg.qps, scfg.duration_s, seed=scfg.seed,
                              prompt_mean=scfg.prompt_mean,
                              output_mean=scfg.output_mean)
    sample = trace.take(scfg.search_sample)

    n_sub = len(cluster.subclusters)
    priced = {ci: _price_pool(arch_cfg, cluster, ci, "mixed", layers, scfg,
                              cost_cfg, cache, comm) for ci in range(n_sub)}

    best_plan: Optional[ServePlan] = None
    best_score = float("inf")
    n_cands = 0
    for roles in itertools.product(ROLES, repeat=n_sub):
        if all(r == "off" for r in roles):
            continue
        plan = _assemble(arch_cfg, roles, priced, comm, layers, scfg,
                         routing="least_loaded")
        if plan is None:
            continue
        n_cands += 1
        res = simulate_trace(plan, sample)
        s = score(res, scfg.objective, slo_ttft_s=scfg.slo_ttft_s,
                  slo_tpot_s=scfg.slo_tpot_s)
        if verbose:
            print(f"[serving] roles={roles} score={s:.4g} "
                  f"p99_ttft={res.p99_ttft_s * 1e3:.1f}ms "
                  f"rejected={res.n_rejected}")
        if better(s, best_score):
            best_score, best_plan = s, dataclasses.replace(
                plan, predicted=res.summary())
    if best_plan is None:
        raise ValueError(
            f"no feasible serving placement for {arch_cfg.arch_id} on "
            f"{cluster.describe()} ({n_sub} pools all infeasible)")
    base = colocated_plan(arch_cfg, cluster, scfg, comm=comm, layers=layers,
                          cost_cache=cache)
    base_res = simulate_trace(base, sample)
    best_plan.baseline = base_res.summary()
    if verbose:
        print(f"[serving] searched {n_cands} candidates; best score "
              f"{best_score:.4g}")
    return best_plan
