"""KV-cache capacity bound — the serving analog of Eq. 18.

Training's memory feasibility (``core.costmodel.memory_feasible``) bounds
``mem_p + K * mem_a`` per device; serving's bound is

    weights + sum_over_active_seqs(kv_footprint(seq)) <= headroom * pool_mem

where a sequence's footprint has a *growing* part (attention KV: bytes per
cached token, matching the ``models.*.init_cache`` array shapes byte for
byte for the dense/MoE families) and a *fixed* part (Mamba-2 SSM state and
conv tail in f32; VLM image-memory KV; audio encoder-memory KV).

Accounting is **paged** (vLLM-style): KV is reserved in blocks of
``block_tokens`` tokens, so the capacity constraint is an integer block
budget per pool and a request's reservation is block-rounded.  Admission
control reserves a request's *worst-case* blocks (prompt + full output)
before its first decode step — conservative, so the simulator can assert
the bound is never violated rather than model preemption.

Windowed attention layers (``sliding_window`` / ``local_global_ratio``) are
charged at the full-attention rate: a capacity bound may over-reserve but
must never under-reserve, and the planner has no per-layer eviction model.

Units: bytes, tokens.  No jax imports.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ArchConfig
from repro.core.cluster import SubCluster


def _n_attn_layers(cfg: ArchConfig) -> int:
    """Layers that append per-token KV during decode."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        # zamba2: the shared transformer block runs every k SSM layers and
        # each application keeps its own KV
        return cfg.n_layers // cfg.shared_attn_every \
            if cfg.shared_attn_every else 0
    if cfg.family == "vlm" and cfg.cross_attn_every:
        # every cross_attn_every-th layer is cross-attention (image memory,
        # a fixed cost in state_bytes_per_seq) — it REPLACES the self-attn
        # layer, so it appends no per-token KV
        return cfg.n_layers - cfg.n_layers // cfg.cross_attn_every
    return cfg.n_layers


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: float = 2.0) -> float:
    """Attention KV bytes appended per cached token: K and V heads across
    every KV-bearing layer.  Matches the dense/MoE decode caches
    (``(n_layers, B, S, n_kv_heads, head_dim)`` x2) exactly."""
    return _n_attn_layers(cfg) * 2.0 * cfg.kv_dim * dtype_bytes


def state_bytes_per_seq(cfg: ArchConfig, dtype_bytes: float = 2.0) -> float:
    """Fixed (seq-length-independent) per-sequence state bytes.

    - Mamba-2 SSD state: per layer, f32 ``(n_heads, head_dim, d_state)``
      state plus the ``(d_conv - 1, d_inner + 2*d_state)`` conv tail
      (``models.ssm.ssm_init_state`` shapes);
    - VLM cross-attention image-memory KV (``n_image_tokens`` per cross
      layer) and audio encoder-memory KV (``enc_frames`` per decoder
      layer), both at the cache dtype.
    """
    total = 0.0
    if cfg.ssm_state and cfg.family in ("ssm", "hybrid"):
        per_layer = (cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                     + (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state))
        total += cfg.n_layers * 4.0 * per_layer       # f32 state
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        total += n_cross * 2.0 * cfg.kv_dim * dtype_bytes * cfg.n_image_tokens
    if cfg.enc_layers:
        total += cfg.n_layers * 2.0 * cfg.kv_dim * dtype_bytes * cfg.enc_frames
    return total


def kv_cache_bytes(cfg: ArchConfig, seq_len: int,
                   dtype_bytes: float = 2.0) -> float:
    """Un-paged per-sequence footprint at context ``seq_len`` (what a
    prefill→decode handoff actually ships)."""
    return seq_len * kv_bytes_per_token(cfg, dtype_bytes) \
        + state_bytes_per_seq(cfg, dtype_bytes)


@dataclass(frozen=True)
class KVBound:
    """One pool's paged KV budget: ``blocks_capacity`` blocks of
    ``block_bytes`` each, after weights and headroom."""
    block_bytes: float
    blocks_capacity: int

    def fits(self, used_blocks: int, new_blocks: int) -> bool:
        return used_blocks + new_blocks <= self.blocks_capacity


def block_bytes(cfg: ArchConfig, block_tokens: int,
                dtype_bytes: float = 2.0) -> float:
    """Bytes of one paged block.  KV-bearing families: ``block_tokens``
    tokens of KV.  Attention-free (pure SSM): the block *is* one sequence's
    fixed state — paging degenerates to per-sequence slots."""
    per_tok = kv_bytes_per_token(cfg, dtype_bytes)
    if per_tok > 0:
        return block_tokens * per_tok
    return max(state_bytes_per_seq(cfg, dtype_bytes), 1.0)


def blocks_for_seq(cfg: ArchConfig, seq_tokens: int, block_tokens: int,
                   dtype_bytes: float = 2.0) -> int:
    """Blocks a sequence with ``seq_tokens`` of context reserves: its KV
    block-rounded, plus whole blocks covering the fixed state."""
    bb = block_bytes(cfg, block_tokens, dtype_bytes)
    per_tok = kv_bytes_per_token(cfg, dtype_bytes)
    if per_tok <= 0:
        return 1
    kv_blocks = math.ceil(seq_tokens / block_tokens)
    state = state_bytes_per_seq(cfg, dtype_bytes)
    return kv_blocks + (math.ceil(state / bb) if state > 0 else 0)


def decode_capacity(cfg: ArchConfig, sub: SubCluster, *, weights_bytes: float,
                    block_tokens: int, dtype_bytes: float = 2.0,
                    mem_headroom: float = 0.9) -> KVBound:
    """The pool's Eq.-18-analog budget: blocks that fit in
    ``headroom * pool_mem - weights`` (0 when the weights alone don't fit —
    the placement search drops such pools as decode-infeasible)."""
    bb = block_bytes(cfg, block_tokens, dtype_bytes)
    free = mem_headroom * sub.n_devices * sub.device.mem_bytes - weights_bytes
    return KVBound(block_bytes=bb,
                   blocks_capacity=max(0, int(free // bb)))
