"""Event-driven continuous-batching simulator (``comm/netsim.py`` style).

One global event heap drives per-pool serial engines.  Each pool runs one
unit of work at a time:

- a *prefill chunk* (``ServePlan.prefill_chunk`` tokens of the queue-head
  request, priced by the pool's ``prefill_chunk_s``), or
- a *decode step* (one token for every active sequence, priced by the
  roofline ``max(weights+KV reads / HBM, batch FLOPs / decode FLOPs)`` —
  small batches are bandwidth-bound on the weight sweep, large batches
  turn compute-bound).

``mixed`` pools alternate the two when both kinds of work are pending —
the prefill-decode interference that disaggregated placement removes.

Admission control (never OOM, the Eq.-18-analog contract):

- arrivals whose routed prefill queue is at ``max_queue`` are rejected;
- a finished prefill reserves its sequence's *worst-case* paged blocks
  (prompt + full output, :meth:`ServePlan.seq_blocks`) before its first
  decode step; requests that can never fit any decode pool are rejected,
  requests that transiently don't fit wait in the pool's ready queue
  (bounded by ``max_queue``, rejected beyond);
- the simulator asserts ``blocks_used <= blocks_capacity`` after every
  reservation and reports the violation count (always 0 by construction).

Prefill→decode KV handoff is priced through the plan's link tables
(:meth:`ServePlan.handoff_seconds`); same-pool handoff is free.

Determinism: the heap is keyed (time, seq#); no randomness anywhere, so a
(plan, trace) pair always produces identical samples.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.serving.objective import percentile
from repro.serving.placement import ServePlan
from repro.serving.workload import Request, ServeTrace


@dataclass
class _Seq:
    """Mutable per-request simulation state."""
    req: Request
    prefill_left: int
    prefill_pool: int = -1
    decode_pool: int = -1
    blocks: int = 0
    ctx: int = 0                  # tokens currently cached
    done: int = 0                 # output tokens produced
    t_first: float = -1.0
    t_last: float = -1.0


class _Pool:
    """One serial pool engine."""

    def __init__(self, idx: int, spec):
        self.idx = idx
        self.spec = spec
        self.prefill_q: deque = deque()     # _Seq awaiting/under prefill
        self.ready: deque = deque()         # _Seq with KV landed, not active
        self.active: List[_Seq] = []
        self.blocks_used = 0
        self.pending_blocks = 0             # ready + in-flight handoffs
        self.peak_blocks = 0
        self.queued_prefill_tokens = 0
        self.busy = False
        self.last_prefill = False
        self.busy_prefill_s = 0.0
        self.busy_decode_s = 0.0
        self.sum_ctx = 0

    @property
    def free_blocks_for_routing(self) -> int:
        return self.spec.blocks_capacity - self.blocks_used \
            - self.pending_blocks


@dataclass
class ServeSimResult:
    """Per-request latency samples + capacity/occupancy accounting."""
    n_completed: int
    n_rejected: int
    ttft_s: List[float]
    tpot_s: List[float]
    makespan_s: float
    completed_output_tokens: int
    goodput_output_tokens: int
    slo_ttft_s: float
    slo_tpot_s: float
    peak_blocks: Dict[str, int] = field(default_factory=dict)
    blocks_capacity: Dict[str, int] = field(default_factory=dict)
    kv_violations: int = 0
    n_handoffs: int = 0
    handoff_bytes: float = 0.0
    pool_busy_s: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def p50_ttft_s(self) -> float:
        return percentile(self.ttft_s, 50)

    @property
    def p99_ttft_s(self) -> float:
        return percentile(self.ttft_s, 99)

    @property
    def p50_tpot_s(self) -> float:
        return percentile(self.tpot_s, 50)

    @property
    def p99_tpot_s(self) -> float:
        return percentile(self.tpot_s, 99)

    @property
    def throughput_tokens_per_s(self) -> float:
        return self.completed_output_tokens / self.makespan_s \
            if self.makespan_s > 0 else 0.0

    @property
    def goodput_tokens_per_s(self) -> float:
        """Output tokens/s of requests that met both SLOs."""
        return self.goodput_output_tokens / self.makespan_s \
            if self.makespan_s > 0 else 0.0

    def summary(self) -> Dict[str, Any]:
        """JSON-stable digest (rides on ``ServePlan.predicted``)."""
        return {
            "n_completed": self.n_completed,
            "n_rejected": self.n_rejected,
            "p50_ttft_s": self.p50_ttft_s,
            "p99_ttft_s": self.p99_ttft_s,
            "p50_tpot_s": self.p50_tpot_s,
            "p99_tpot_s": self.p99_tpot_s,
            "makespan_s": self.makespan_s,
            "throughput_tokens_per_s": self.throughput_tokens_per_s,
            "goodput_tokens_per_s": self.goodput_tokens_per_s,
            "kv_violations": self.kv_violations,
            "n_handoffs": self.n_handoffs,
            "handoff_bytes": self.handoff_bytes,
            "peak_blocks": dict(self.peak_blocks),
        }

    def describe(self) -> str:
        return (f"{self.n_completed} completed / {self.n_rejected} rejected; "
                f"p99 TTFT {self.p99_ttft_s * 1e3:.1f} ms, "
                f"p99 TPOT {self.p99_tpot_s * 1e3:.2f} ms, "
                f"goodput {self.goodput_tokens_per_s:,.0f} tok/s "
                f"over {self.makespan_s:.2f} s")


def _decode_step_seconds(plan: ServePlan, pool: _Pool) -> float:
    """Roofline decode step: every active sequence reads the weights once
    (amortized across the batch) plus its own KV; compute is the batch's
    GEMV flops."""
    spec = pool.spec
    kv_bytes = pool.sum_ctx * plan.kv_bytes_per_token \
        + len(pool.active) * plan.state_bytes_per_seq
    t_mem = (spec.weights_bytes + kv_bytes) / spec.hbm_bytes_per_s
    t_flops = len(pool.active) * plan.flops_per_token / spec.decode_flops_per_s
    return max(t_mem, t_flops) + plan.step_overhead_s


def simulate_trace(plan: ServePlan, trace: ServeTrace,
                   recorder: Optional[List] = None) -> ServeSimResult:
    """Replay ``trace`` against ``plan``; deterministic.

    ``recorder`` (a list, appended in dispatch order) captures every
    prefill chunk and decode step as
    ``(t, dur, pool_idx, pool_name, kind, n)`` tuples — the raw material
    ``obs.trace_from_serve`` turns into per-pool Chrome-trace lanes.
    ``recorder=None`` (the default) changes nothing."""
    pools = [_Pool(i, spec) for i, spec in enumerate(plan.pools)]
    prefill_pools = [p for p in pools if p.spec.can_prefill]
    decode_pools = [p for p in pools if p.spec.can_decode]
    if not prefill_pools or not decode_pools:
        raise ValueError("ServePlan needs >=1 prefill-capable and >=1 "
                         "decode-capable pool")

    events: List = []               # (t, seq#, kind, payload)
    seq_no = 0

    def push(t: float, kind: str, payload) -> None:
        nonlocal seq_no
        heapq.heappush(events, (t, seq_no, kind, payload))
        seq_no += 1

    ttft: List[float] = []
    tpot: List[float] = []
    n_rejected = 0
    n_completed = 0
    completed_tokens = 0
    goodput_tokens = 0
    kv_violations = 0
    n_handoffs = 0
    handoff_bytes = 0.0
    makespan = 0.0
    rr_counter = 0                  # uniform routing cursor

    # -- routing -------------------------------------------------------------

    def route_prefill(s: _Seq) -> _Pool:
        nonlocal rr_counter
        if plan.routing == "uniform":
            pool = prefill_pools[rr_counter % len(prefill_pools)]
            rr_counter += 1
            return pool
        # least_loaded: smallest estimated queue drain time (queued tokens
        # at the pool's per-chunk rate); ties break on pool index
        return min(prefill_pools, key=lambda p: (
            (p.queued_prefill_tokens + s.req.prompt_tokens)
            * p.spec.prefill_chunk_s / plan.prefill_chunk,
            p.idx))

    def route_decode(s: _Seq, src: _Pool) -> Optional[_Pool]:
        blocks = plan.seq_blocks(s.req.prompt_tokens + s.req.output_tokens)
        if all(blocks > p.spec.blocks_capacity for p in decode_pools):
            return None             # can never fit anywhere
        if plan.routing == "uniform" and src.spec.can_decode:
            return src              # colocated: decode where you prefilled
        fits = [p for p in decode_pools if blocks <= p.spec.blocks_capacity
                and len(p.ready) < plan.max_queue]
        if not fits:
            return None             # every eligible ready queue is full
        # most free KV blocks wins; prefer the source pool on ties (free
        # handoff), then the lowest index
        return max(fits, key=lambda p: (p.free_blocks_for_routing,
                                        p is src, -p.idx))

    # -- pool engine ---------------------------------------------------------

    def admit(pool: _Pool) -> None:
        nonlocal kv_violations
        while pool.ready:
            s = pool.ready[0]
            if pool.blocks_used + s.blocks > pool.spec.blocks_capacity:
                break               # head-of-line waits for blocks to free
            pool.ready.popleft()
            pool.blocks_used += s.blocks
            pool.pending_blocks -= s.blocks
            if pool.blocks_used > pool.spec.blocks_capacity:
                kv_violations += 1  # unreachable by construction; counted
            pool.peak_blocks = max(pool.peak_blocks, pool.blocks_used)
            s.ctx = s.req.prompt_tokens
            pool.sum_ctx += s.ctx
            pool.active.append(s)

    def dispatch(pool: _Pool, t: float) -> None:
        if pool.busy:
            return
        admit(pool)
        has_prefill = pool.spec.can_prefill and bool(pool.prefill_q)
        has_decode = pool.spec.can_decode and bool(pool.active)
        if has_prefill and has_decode:
            do_prefill = not pool.last_prefill    # alternate: interference
        else:
            do_prefill = has_prefill
        if do_prefill:
            s = pool.prefill_q[0]
            chunk = min(s.prefill_left, plan.prefill_chunk)
            dur = pool.spec.prefill_chunk_s * chunk / plan.prefill_chunk
            pool.busy = True
            pool.last_prefill = True
            pool.busy_prefill_s += dur
            if recorder is not None:
                recorder.append((t, dur, pool.idx, pool.spec.name,
                                 "prefill", chunk))
            push(t + dur, "chunk", (pool.idx, s, chunk))
        elif has_decode:
            dur = _decode_step_seconds(plan, pool)
            pool.busy = True
            pool.last_prefill = False
            pool.busy_decode_s += dur
            if recorder is not None:
                recorder.append((t, dur, pool.idx, pool.spec.name,
                                 "decode", len(pool.active)))
            push(t + dur, "step", (pool.idx, list(pool.active)))

    # -- event handlers ------------------------------------------------------

    def on_arrive(t: float, s: _Seq) -> None:
        nonlocal n_rejected
        pool = route_prefill(s)
        if len(pool.prefill_q) >= plan.max_queue:
            n_rejected += 1
            return
        s.prefill_pool = pool.idx
        pool.prefill_q.append(s)
        pool.queued_prefill_tokens += s.req.prompt_tokens
        dispatch(pool, t)

    def on_chunk(t: float, pool: _Pool, s: _Seq, chunk: int) -> None:
        nonlocal n_rejected, n_handoffs, handoff_bytes
        pool.busy = False
        s.prefill_left -= chunk
        pool.queued_prefill_tokens -= chunk
        if s.prefill_left <= 0:
            pool.prefill_q.popleft()
            dst = route_decode(s, pool)
            if dst is None:
                n_rejected += 1
            else:
                s.decode_pool = dst.idx
                s.blocks = plan.seq_blocks(
                    s.req.prompt_tokens + s.req.output_tokens)
                dst.pending_blocks += s.blocks
                nbytes = plan.seq_kv_bytes(s.req.prompt_tokens)
                delay = plan.handoff_seconds(pool.idx, dst.idx, nbytes)
                if dst.idx != pool.idx:
                    n_handoffs += 1
                    handoff_bytes += nbytes
                push(t + delay, "ready", (dst.idx, s))
        dispatch(pool, t)

    def on_ready(t: float, pool: _Pool, s: _Seq) -> None:
        pool.ready.append(s)
        dispatch(pool, t)

    def on_step(t: float, pool: _Pool, batch: List[_Seq]) -> None:
        nonlocal n_completed, completed_tokens, goodput_tokens, makespan
        pool.busy = False
        for s in batch:
            s.done += 1
            s.ctx += 1
            pool.sum_ctx += 1
            if s.t_first < 0:
                s.t_first = t
            if s.done >= s.req.output_tokens:
                s.t_last = t
                pool.active.remove(s)
                pool.sum_ctx -= s.ctx
                pool.blocks_used -= s.blocks
                n_completed += 1
                completed_tokens += s.req.output_tokens
                makespan = max(makespan, t)
                t_ttft = s.t_first - s.req.arrival_s
                ttft.append(t_ttft)
                ok = t_ttft <= plan.slo_ttft_s
                if s.req.output_tokens > 1:
                    t_tpot = (s.t_last - s.t_first) \
                        / (s.req.output_tokens - 1)
                    tpot.append(t_tpot)
                    ok = ok and t_tpot <= plan.slo_tpot_s
                if ok:
                    goodput_tokens += s.req.output_tokens
        dispatch(pool, t)

    # -- run -----------------------------------------------------------------

    for r in trace.requests:
        push(r.arrival_s, "arrive",
             _Seq(req=r, prefill_left=r.prompt_tokens))

    while events:
        t, _, kind, payload = heapq.heappop(events)
        if kind == "arrive":
            on_arrive(t, payload)
        elif kind == "chunk":
            pidx, s, chunk = payload
            on_chunk(t, pools[pidx], s, chunk)
        elif kind == "ready":
            pidx, s = payload
            on_ready(t, pools[pidx], s)
        else:
            pidx, batch = payload
            on_step(t, pools[pidx], batch)

    return ServeSimResult(
        n_completed=n_completed, n_rejected=n_rejected,
        ttft_s=ttft, tpot_s=tpot, makespan_s=makespan,
        completed_output_tokens=completed_tokens,
        goodput_output_tokens=goodput_tokens,
        slo_ttft_s=plan.slo_ttft_s, slo_tpot_s=plan.slo_tpot_s,
        peak_blocks={p.spec.name: p.peak_blocks for p in pools},
        blocks_capacity={p.spec.name: p.spec.blocks_capacity for p in pools},
        kv_violations=kv_violations,
        n_handoffs=n_handoffs, handoff_bytes=handoff_bytes,
        pool_busy_s={p.spec.name: {"prefill": p.busy_prefill_s,
                                   "decode": p.busy_decode_s}
                     for p in pools})
