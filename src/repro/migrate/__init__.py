"""Plan-to-plan live state migration (ROADMAP item 2).

``layout`` maps a (strategy, cluster) pair to per-device byte-interval
holdings of every parameter/optimizer leaf; ``differ`` emits the minimal
typed transfer set between two layouts; ``pricing`` prices it through the
comm subsystem's tiered links + fair-share netsim, overlapped with the old
plan's drain; ``apply`` is the host-side reference executor the
bit-identity tests run.

Front door used by the ElasticController and ``Executable.migrate_to``:

    old = layout_from_strategy(old_strategy, old_cluster, layers)
    new = layout_from_strategy(new_strategy, new_cluster, layers)
    mplan = diff_layouts(old, new, lost=lost_devices(old_cluster,
                                                     new_cluster))
    cost = price_migration(mplan, old, new_cluster,
                           old_strategy=old_strategy,
                           old_cluster=old_cluster, layers=layers)
    # cost.downtime_s -> amortization rule; mplan.moved_bytes -> decision
"""
from repro.migrate.apply import (
    ApplyStats, MigrationAborted, RetryPolicy, ShardedState, apply_migration,
    gather_leaf, shard_state, states_equal,
)
from repro.migrate.differ import MigrationPlan, Transfer, diff_layouts
from repro.migrate.layout import (
    DeviceId, LeafSpec, PlanLayout, layout_from_strategy, lost_devices,
    stage_devices, stage_intra,
)
from repro.migrate.pricing import (
    DEFAULT_RESTORE_BW, MigrationCost, classify_link, price_migration,
)

__all__ = [
    "ApplyStats", "DeviceId", "LeafSpec", "MigrationAborted", "MigrationCost",
    "MigrationPlan", "PlanLayout", "RetryPolicy", "ShardedState", "Transfer",
    "apply_migration",
    "classify_link", "diff_layouts", "gather_leaf", "layout_from_strategy",
    "lost_devices", "price_migration", "shard_state", "stage_devices",
    "stage_intra", "states_equal", "DEFAULT_RESTORE_BW",
]
