"""Layout differ: old plan's holdings -> new plan's holdings, moved bytes
only.

For every byte a device must hold under the new layout:

1. if the *same physical device* already holds it under the old layout
   (and survived the fleet event), nothing moves — ``local_bytes``;
2. else the byte ships from the nearest surviving old holder — same node
   beats same sub-cluster beats cross-cluster (ties broken by device id,
   so the diff is deterministic);
3. a byte with no surviving holder (its replicas all sat on lost nodes)
   is restored from the newest checkpoint — ``src=None`` transfers,
   priced over the restore path instead of a fleet link.

Adjacent byte runs with the same (src, dst) pair merge into one
:class:`Transfer`, so the transfer set is minimal *and* small.  The
moved-bytes bound — ``moved_bytes`` equals the exact sum of live transfer
sizes, and no correct executor can ship fewer bytes to materialize the new
layout from the old one — is the invariant the property tests pin
(DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.migrate.layout import (
    DeviceId, Interval, PlanLayout, intersect, length, normalize, subtract,
)


@dataclass(frozen=True)
class Transfer:
    """One contiguous byte run of one leaf moving to one device.
    ``src=None`` means no live replica survived: restore from checkpoint."""
    leaf: str
    start: int
    end: int                       # exclusive
    dst: DeviceId
    src: Optional[DeviceId] = None

    @property
    def nbytes(self) -> int:
        return self.end - self.start


@dataclass
class MigrationPlan:
    """The typed transfer set between two layouts plus exact byte
    accounting: ``moved_bytes`` (live device-to-device traffic),
    ``ckpt_bytes`` (checkpoint-restored), ``local_bytes`` (already in
    place), ``total_bytes`` (everything the new layout holds);
    ``moved + ckpt + local == total`` always."""
    transfers: List[Transfer] = field(default_factory=list)
    moved_bytes: int = 0
    ckpt_bytes: int = 0
    local_bytes: int = 0
    total_bytes: int = 0

    @property
    def n_transfers(self) -> int:
        return len(self.transfers)

    @property
    def moved_fraction(self) -> float:
        return self.moved_bytes / self.total_bytes if self.total_bytes else 0.0

    def describe(self) -> str:
        mb = 1e6
        return (f"migration: {self.moved_bytes / mb:.1f} MB moved in "
                f"{self.n_transfers} transfers, "
                f"{self.local_bytes / mb:.1f} MB in place, "
                f"{self.ckpt_bytes / mb:.1f} MB from checkpoint "
                f"({self.moved_fraction:.0%} of state on the wire)")


def _source_rank(lay_old: PlanLayout, src: DeviceId, dst: DeviceId) -> Tuple:
    """Preference key for choosing among surviving holders (lower wins):
    same node < same sub-cluster < cross-cluster, then device id for
    determinism."""
    if src[0] != dst[0]:
        return (3, src)
    dpn = lay_old.devices_per_node.get(src[0], 1)
    same_node = src[1] // dpn == dst[1] // dpn
    return (1 if same_node else 2, src)


def _cover(leaf: str, frag: Interval, dst: DeviceId,
           holders: List[Tuple[DeviceId, List[Interval]]],
           lay_old: PlanLayout) -> List[Transfer]:
    """Cover one missing fragment from the best overlapping holders: walk
    from ``frag.start``, at each position pick the preferred source whose
    interval covers it, and extend the transfer as far as that source
    goes.  Positions no holder covers become checkpoint restores."""
    out: List[Transfer] = []
    pos, end = frag
    while pos < end:
        best: Optional[Tuple[Tuple, DeviceId, int]] = None
        nxt = end                       # nearest upcoming holder start
        for dev, ivs in holders:
            for s, e in ivs:
                if s <= pos < e:
                    rank = _source_rank(lay_old, dev, dst)
                    if best is None or rank < best[0]:
                        best = (rank, dev, min(e, end))
                elif pos < s < nxt:
                    nxt = s
        if best is None:
            out.append(Transfer(leaf, pos, nxt, dst, src=None))
            pos = nxt
            continue
        _, dev, stop = best
        if out and out[-1].src == dev and out[-1].end == pos:
            out[-1] = Transfer(leaf, out[-1].start, stop, dst, src=dev)
        else:
            out.append(Transfer(leaf, pos, stop, dst, src=dev))
        pos = stop
    return out


def diff_layouts(old: PlanLayout, new: PlanLayout,
                 lost: Optional[Set[DeviceId]] = None) -> MigrationPlan:
    """The minimal transfer set turning ``old``'s holdings into ``new``'s
    (module docstring).  ``lost`` devices are excluded as sources — their
    bytes must come from surviving replicas or the checkpoint."""
    lost = lost or set()
    plan = MigrationPlan()
    for leaf, hold_new in new.holdings.items():
        hold_old = old.holdings.get(leaf, {})
        live = sorted(
            ((dev, ivs) for dev, ivs in hold_old.items() if dev not in lost),
            key=lambda kv: kv[0])
        for dst in sorted(hold_new):
            need = normalize(hold_new[dst])
            plan.total_bytes += length(need)
            already = intersect(need, hold_old.get(dst, [])) \
                if dst not in lost else []
            plan.local_bytes += length(already)
            for frag in subtract(need, already):
                for t in _cover(leaf, frag, dst, live, old):
                    plan.transfers.append(t)
                    if t.src is None:
                        plan.ckpt_bytes += t.nbytes
                    else:
                        plan.moved_bytes += t.nbytes
    return plan
