"""Shard-interval layouts: where every parameter/optimizer byte lives
under a plan.

A :class:`PlanLayout` maps each state *leaf* (one planner layer's parameter
block, or its optimizer-state block) to the byte intervals every physical
device holds under a ``(ParallelStrategy, HeteroCluster)`` pair:

- **params** are split into ``tp`` contiguous byte slices (tensor
  parallelism) and replicated across the ``dp`` data shards — every data
  shard holds its tp-slice in full;
- **optimizer state** (ZeRO-1 style, ``opt_bytes_per_param`` x the
  parameter bytes) is additionally sharded across the ``dp`` ranks in
  proportion to the stage's ``IntraOpPlan.shard_ratios`` — the same uneven
  efficiency-proportional split the planner chose for the microbatch, so
  the per-step optimizer update work lands where the compute headroom is.

All splits use exact integer largest-remainder apportionment
(:func:`repro.parallel.sharding.apportion`), which is what makes the
layout differ's transfers reproduce the target layout *bit-identically*
(asserted by the property tests in ``tests/test_migrate.py``).

Device identity is ``(subcluster_name, device_index)``: stages placed on
the same sub-cluster occupy consecutive device ranges in stage order, and
within a stage the flat index follows the ``mesh_from_intra_op`` contract
(``dp_rank * tp + tp_rank``) — so the same physical device is recognized
across two plans and bytes it already holds are never re-shipped.
Node index is ``device_index // devices_per_node`` (link classification:
same node -> ``intra:{name}``, same sub-cluster -> ``ib:{name}``, else the
shared ``wan`` — see ``repro.migrate.pricing``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.cluster import HeteroCluster
from repro.core.layering import Layer
from repro.core.strategy import IntraOpPlan, ParallelStrategy, StageAssignment
from repro.parallel.sharding import apportion

DeviceId = Tuple[str, int]     # (subcluster name, device index within it)
Interval = Tuple[int, int]     # [start, end) in bytes

# ZeRO-1 default: fp32 Adam moments (m, v) alongside the parameters
OPT_BYTES_PER_PARAM = 2.0


@dataclass(frozen=True)
class LeafSpec:
    """One migratable state block: a planner layer's parameters or its
    optimizer state.  ``nbytes`` is the full (unsharded) size."""
    name: str
    nbytes: int
    kind: str                  # "param" | "opt"
    layer: int                 # planner layer index


@dataclass
class PlanLayout:
    """Byte-interval holdings of every device under one plan.

    ``holdings[leaf][device]`` is a sorted, disjoint, non-empty interval
    list; ``leaf_stage[leaf]`` the owning pipeline stage (release ordering
    for the overlap scheduler); ``devices_per_node`` keys link
    classification."""
    leaves: Dict[str, LeafSpec] = field(default_factory=dict)
    holdings: Dict[str, Dict[DeviceId, List[Interval]]] = \
        field(default_factory=dict)
    leaf_stage: Dict[str, int] = field(default_factory=dict)
    devices_per_node: Dict[str, int] = field(default_factory=dict)

    def add(self, spec: LeafSpec, stage: int,
            per_device: Dict[DeviceId, List[Interval]]) -> None:
        if spec.name in self.leaves:
            raise ValueError(f"duplicate leaf {spec.name!r}")
        self.leaves[spec.name] = spec
        self.leaf_stage[spec.name] = stage
        self.holdings[spec.name] = {
            d: ivs for d, ivs in per_device.items() if ivs}

    def node_of(self, dev: DeviceId) -> Tuple[str, int]:
        name, idx = dev
        return (name, idx // self.devices_per_node[name])

    @property
    def total_bytes(self) -> int:
        """Sum of all held bytes across devices (replicas counted)."""
        return sum(e - s for hold in self.holdings.values()
                   for ivs in hold.values() for s, e in ivs)

    def devices(self) -> Set[DeviceId]:
        out: Set[DeviceId] = set()
        for hold in self.holdings.values():
            out.update(hold.keys())
        return out


# ---------------------------------------------------------------------------
# Interval arithmetic (sorted disjoint [start, end) lists)
# ---------------------------------------------------------------------------


def normalize(ivs: Sequence[Interval]) -> List[Interval]:
    """Sorted, merged, empties dropped."""
    out: List[Interval] = []
    for s, e in sorted((s, e) for s, e in ivs if e > s):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def intersect(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    out: List[Interval] = []
    i = j = 0
    a, b = list(a), list(b)
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if s < e:
            out.append((s, e))
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return out


def subtract(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    """Bytes of ``a`` not covered by ``b``."""
    out: List[Interval] = []
    b = list(b)
    j = 0
    for s, e in a:
        cur = s
        while j < len(b) and b[j][1] <= cur:
            j += 1
        k = j
        while k < len(b) and b[k][0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if cur >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return out


def length(ivs: Sequence[Interval]) -> int:
    return sum(e - s for s, e in ivs)


# ---------------------------------------------------------------------------
# Layout construction
# ---------------------------------------------------------------------------


def stage_intra(s: StageAssignment) -> IntraOpPlan:
    """The stage's intra-op plan, or an even degenerate one for inter-only
    strategies (mirrors the api facade's lowering fallback; kept here so
    ``repro.migrate`` does not depend on ``repro.api``)."""
    if s.intra_op is not None:
        return s.intra_op
    tp = max(1, s.tp)
    if s.n_devices % tp != 0:
        tp = 1
    dp = s.n_devices // tp
    return IntraOpPlan(axis="data" if dp >= tp else "tensor", tp=tp, dp=dp,
                       shard_ratios=(1.0 / dp,) * dp,
                       comm_bytes=0.0, comm_time_f=0.0, comm_time_b=0.0)


def stage_devices(strategy: ParallelStrategy, cluster: HeteroCluster
                  ) -> List[List[DeviceId]]:
    """Per stage, the physical devices it occupies: stages sharing a
    sub-cluster take consecutive index ranges in stage order; within a
    stage, flat index ``k`` is data shard ``k // tp``, tp rank ``k % tp``
    (the ``mesh_from_intra_op`` reshape order)."""
    next_free: Dict[str, int] = {}
    out: List[List[DeviceId]] = []
    for s in strategy.stages:
        name = cluster.subclusters[s.cluster_idx].name
        off = next_free.get(name, 0)
        out.append([(name, off + k) for k in range(s.n_devices)])
        next_free[name] = off + s.n_devices
    return out


def layout_from_strategy(strategy: ParallelStrategy, cluster: HeteroCluster,
                         layers: Sequence[Layer], *,
                         opt_bytes_per_param: float = OPT_BYTES_PER_PARAM
                         ) -> PlanLayout:
    """The full shard-interval layout of ``strategy`` on ``cluster``
    (module docstring).  Deterministic: same inputs -> identical layout."""
    lay = PlanLayout(devices_per_node={
        sub.name: sub.devices_per_node for sub in cluster.subclusters})
    devs = stage_devices(strategy, cluster)
    for si, s in enumerate(strategy.stages):
        io = stage_intra(s)
        sdevs = devs[si]
        for li in range(s.layer_start, s.layer_end):
            pb = int(layers[li].param_bytes)
            ob = int(round(pb * opt_bytes_per_param))
            tp_p = apportion(pb, [1.0] * io.tp)
            tp_o = apportion(ob, [1.0] * io.tp)

            # params: tp slice t replicated on every data shard
            hold_p: Dict[DeviceId, List[Interval]] = {}
            off = 0
            for t, sz in enumerate(tp_p):
                if sz > 0:
                    for d in range(io.dp):
                        hold_p[sdevs[d * io.tp + t]] = [(off, off + sz)]
                off += sz
            lay.add(LeafSpec(f"layer{li:04d}.param", pb, "param", li),
                    si, hold_p)

            # optimizer state: each tp slice sharded across dp by the
            # (possibly uneven) shard ratios — no replication
            hold_o: Dict[DeviceId, List[Interval]] = {}
            off = 0
            for t, sz in enumerate(tp_o):
                sub_sizes = apportion(sz, list(io.shard_ratios))
                cur = off
                for d, ssz in enumerate(sub_sizes):
                    if ssz > 0:
                        hold_o[sdevs[d * io.tp + t]] = [(cur, cur + ssz)]
                    cur += ssz
                off += sz
            lay.add(LeafSpec(f"layer{li:04d}.opt", ob, "opt", li), si, hold_o)
    return lay


def lost_devices(old_cluster: HeteroCluster, new_cluster: HeteroCluster
                 ) -> Set[DeviceId]:
    """Devices of ``old_cluster`` that no longer exist in ``new_cluster``
    (sub-cluster shrunk or gone).  ``remove_nodes`` drops *tail* nodes, so
    the lost indices are the tail range — state they held must come from
    surviving replicas or the checkpoint."""
    new_count = {s.name: s.n_devices for s in new_cluster.subclusters}
    lost: Set[DeviceId] = set()
    for sub in old_cluster.subclusters:
        keep = new_count.get(sub.name, 0)
        for i in range(keep, sub.n_devices):
            lost.add((sub.name, i))
    return lost
