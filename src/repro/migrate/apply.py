"""Reference executor for migration plans: host-side byte-level resharding.

Every leaf is a flat byte array (``np.uint8``); a :class:`ShardedState`
keeps, per (leaf, device), the full-size buffer with only the *held*
intervals materialized.  :func:`apply_migration` executes a
:class:`~repro.migrate.differ.MigrationPlan` transfer by transfer —
reading each byte run from the source device's buffer (or the checkpoint
image for ``src=None`` restores) — and counts exactly what went over the
wire, so tests can assert:

- **bit-identity**: the migrated state equals initializing directly in the
  new layout (``shard_state(new_layout, full)``), byte for byte;
- **moved-bytes exactness**: live bytes shipped == the differ's
  ``moved_bytes``, checkpoint bytes == ``ckpt_bytes`` — the bound the
  preemption acceptance test holds the replay to.

This is the semantic ground truth the priced migration models; a real
device runtime would execute the same Transfer list with device puts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.migrate.differ import MigrationPlan
from repro.migrate.layout import (
    DeviceId, Interval, PlanLayout, length, normalize,
)


@dataclass
class ShardedState:
    """Per-(leaf, device) held intervals + backing buffers."""
    layout: PlanLayout
    data: Dict[Tuple[str, DeviceId], np.ndarray] = field(default_factory=dict)
    held: Dict[Tuple[str, DeviceId], List[Interval]] = \
        field(default_factory=dict)

    def buffer(self, leaf: str, dev: DeviceId) -> np.ndarray:
        key = (leaf, dev)
        if key not in self.data:
            self.data[key] = np.zeros(self.layout.leaves[leaf].nbytes,
                                      dtype=np.uint8)
            self.held[key] = []
        return self.data[key]

    def holds(self, leaf: str, dev: DeviceId, start: int, end: int) -> bool:
        for s, e in self.held.get((leaf, dev), []):
            if s <= start and end <= e:
                return True
        return False

    def read(self, leaf: str, dev: DeviceId, start: int, end: int
             ) -> np.ndarray:
        if not self.holds(leaf, dev, start, end):
            raise KeyError(
                f"{dev} does not hold {leaf}[{start}:{end}]")
        return self.data[(leaf, dev)][start:end]

    def write(self, leaf: str, dev: DeviceId, start: int,
              payload: np.ndarray) -> None:
        buf = self.buffer(leaf, dev)
        buf[start:start + len(payload)] = payload
        key = (leaf, dev)
        self.held[key] = normalize(self.held[key]
                                   + [(start, start + len(payload))])


def shard_state(layout: PlanLayout, full: Dict[str, np.ndarray]
                ) -> ShardedState:
    """Direct initialization: place ``full`` leaf byte arrays into
    ``layout``'s holdings (the ground truth the migrated state must
    match)."""
    st = ShardedState(layout)
    for leaf, hold in layout.holdings.items():
        arr = np.asarray(full[leaf], dtype=np.uint8)
        if len(arr) != layout.leaves[leaf].nbytes:
            raise ValueError(
                f"{leaf}: got {len(arr)} bytes, layout expects "
                f"{layout.leaves[leaf].nbytes}")
        for dev, ivs in hold.items():
            for s, e in ivs:
                st.write(leaf, dev, s, arr[s:e])
    return st


@dataclass
class ApplyStats:
    live_bytes: int = 0            # shipped device-to-device
    ckpt_bytes: int = 0            # restored from the checkpoint image
    n_transfers: int = 0
    retries: int = 0               # failed transfer attempts that re-drew
    backoff_s: float = 0.0         # exponential-backoff wall charged
    ckpt_fallbacks: int = 0        # transfers served from the checkpoint
                                   # image after their retry budget drained


@dataclass
class RetryPolicy:
    """Per-transfer retry shaping: attempt ``1 + max_retries`` times, waiting
    ``backoff_s * mult**(attempt - 1)`` before retry ``attempt``."""
    max_retries: int = 3
    backoff_s: float = 0.05
    mult: float = 2.0

    def total_backoff(self, n_retries: int) -> float:
        return sum(self.backoff_s * self.mult ** i for i in range(n_retries))


class MigrationAborted(RuntimeError):
    """A transfer exhausted its retry budget with no checkpoint fallback.
    ``apply_migration`` never mutates the input ``state``, so the caller's
    rollback is simply to keep running the old plan on it — the partial
    ``out`` state is discarded with this exception.  Carries the stats
    accumulated up to the abort (the wasted work to charge)."""

    def __init__(self, msg: str, stats: "ApplyStats"):
        super().__init__(msg)
        self.stats = stats


def apply_migration(state: ShardedState, mplan: MigrationPlan,
                    new_layout: PlanLayout, *,
                    lost: Optional[Set[DeviceId]] = None,
                    ckpt_image: Optional[Dict[str, np.ndarray]] = None,
                    fault_fn=None,
                    retry: Optional[RetryPolicy] = None
                    ) -> Tuple[ShardedState, ApplyStats]:
    """Execute ``mplan`` against ``state`` (the old layout's holdings),
    producing the new layout's state.  Bytes already in place on surviving
    devices are copied locally (not counted as moved); ``src=None``
    restores read ``ckpt_image``; reading from a ``lost`` device raises —
    the differ must never schedule one as a source.

    Fault path: ``fault_fn(transfer, attempt) -> bool`` (True = this attempt
    fails) injects per-transfer failures.  A failed transfer retries with
    exponential backoff per ``retry`` (default :class:`RetryPolicy`); when
    the budget drains it falls back to the checkpoint image for that leaf
    (counted in ``ckpt_fallbacks`` + ``ckpt_bytes``), and when no image
    covers it, raises :class:`MigrationAborted` — ``state`` is untouched,
    so the caller rolls back by keeping the old plan."""
    lost = lost or set()
    retry = retry or RetryPolicy()
    out = ShardedState(new_layout)
    stats = ApplyStats()
    # bytes that never move: same device holds them under both layouts
    for leaf, hold in new_layout.holdings.items():
        for dev, ivs in hold.items():
            if dev in lost:
                raise ValueError(f"new layout places {leaf} on lost {dev}")
            for s, e in ivs:
                for os_, oe in state.held.get((leaf, dev), []):
                    cs, ce = max(s, os_), min(e, oe)
                    if cs < ce:
                        out.write(leaf, dev, cs, state.read(leaf, dev, cs, ce))
    for t in mplan.transfers:
        if t.src is not None and t.src in lost:
            raise ValueError(f"differ scheduled lost device {t.src} "
                             f"as a source for {t.leaf}")
        if t.src is None and (ckpt_image is None or t.leaf not in ckpt_image):
            raise ValueError(
                f"transfer of {t.leaf} needs a checkpoint image "
                f"(no surviving replica)")
        # attempt loop: live read, per-attempt fault draw, exponential
        # backoff, then the checkpoint image as the per-transfer fallback
        attempt = 0
        from_ckpt = t.src is None
        while True:
            if fault_fn is None or from_ckpt \
                    or not fault_fn(t, attempt):
                break
            stats.retries += 1
            stats.backoff_s += retry.backoff_s * retry.mult ** attempt
            attempt += 1
            if attempt > retry.max_retries:
                if ckpt_image is not None and t.leaf in ckpt_image:
                    from_ckpt = True
                    stats.ckpt_fallbacks += 1
                    break
                raise MigrationAborted(
                    f"transfer {t.leaf}[{t.start}:{t.end}] "
                    f"{t.src} -> {t.dst} failed {attempt} times with no "
                    f"checkpoint fallback; rolling back to the old plan",
                    stats)
        if from_ckpt:
            payload = np.asarray(ckpt_image[t.leaf],
                                 dtype=np.uint8)[t.start:t.end]
            stats.ckpt_bytes += t.nbytes
        else:
            payload = state.read(t.leaf, t.src, t.start, t.end)
            stats.live_bytes += t.nbytes
        out.write(t.leaf, t.dst, t.start, np.array(payload, copy=True))
        stats.n_transfers += 1
    return out, stats


def gather_leaf(state: ShardedState, leaf: str) -> np.ndarray:
    """Reconstruct one full leaf from the holdings; raises if any byte is
    uncovered (a layout must tile every leaf completely)."""
    spec = state.layout.leaves[leaf]
    arr = np.zeros(spec.nbytes, dtype=np.uint8)
    covered: List[Interval] = []
    for dev, ivs in state.layout.holdings.get(leaf, {}).items():
        for s, e in ivs:
            arr[s:e] = state.read(leaf, dev, s, e)
            covered.append((s, e))
    covered = normalize(covered)
    if length(covered) != spec.nbytes or \
            (covered and (covered[0][0] != 0 or covered[-1][1] != spec.nbytes)):
        raise ValueError(f"{leaf}: holdings cover {covered}, "
                         f"expected [0, {spec.nbytes})")
    return arr


def states_equal(a: ShardedState, b: ShardedState) -> bool:
    """Bit-identity over every (leaf, device, interval) of ``b``'s
    layout."""
    for leaf, hold in b.layout.holdings.items():
        for dev, ivs in hold.items():
            for s, e in ivs:
                if not a.holds(leaf, dev, s, e):
                    return False
                if not np.array_equal(a.read(leaf, dev, s, e),
                                      b.read(leaf, dev, s, e)):
                    return False
    return True
