"""Exact migration pricing: the differ's transfer set through the comm
subsystem's topology + fair-share netsim, overlapped with the old plan's
drain.

Migration traffic rides the *same* tiered links as training
(``repro.comm.topology``): a transfer between two devices on one node is
``intra:{name}``, across nodes of one sub-cluster ``ib:{name}``, across
sub-clusters the shared ``wan`` (with its per-transfer latency).
Checkpoint-restored bytes (no surviving replica) ride a dedicated restore
path at ``restore_bw``.

The **overlap scheduler** prices the migration *against the tail of the
old plan's final step* instead of stop-the-world:

- each old stage's parameters are final only after its last microbatch
  backward + its per-step gradient sync — late pipeline stages finish their
  backwards early (1F1B), so their shards prefetch while early stages are
  still draining;
- the drain's own traffic (remaining boundary activation sends, gradient
  syncs on their physical links) contends fairly with migration flows that
  share a link — a WAN-crossing migration slows under the WAN sync it
  overlaps, exactly as ``repro.comm.netsim`` resolves it;
- transfers between one (src, dst) device pair ride one connection (one
  fair-share flow), released when the source stage's state is final.

``charged downtime = max(0, overlapped makespan - drain-alone makespan)``
— the wall clock the ElasticController bills to the amortization rule; the
old plan was going to spend the drain regardless.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.comm.netsim import SimNode, run
from repro.comm.selector import collective_breakdown
from repro.comm.topology import CROSS_LINK, Topology, build_topology
from repro.core.cluster import HeteroCluster
from repro.core.layering import Layer
from repro.core.pipesim import simulate
from repro.core.strategy import ParallelStrategy
from repro.migrate.differ import MigrationPlan, Transfer
from repro.migrate.layout import DeviceId, PlanLayout

RESTORE_LINK = "__restore__"           # shared checkpoint-restore path
DEFAULT_RESTORE_BW = 2e9               # bytes/s off the checkpoint store


@dataclass
class MigrationCost:
    """Priced migration.  ``downtime_s`` is what the controller charges:
    the overlapped extra wall beyond the old plan's own drain (or the
    serial time when overlap pricing is off)."""
    serial_s: float                    # stop-the-world: migration alone
    overlap_extra_s: float             # extra wall beyond the drain
    drain_s: float                     # old plan's final-step drain alone
    link_bytes: Dict[str, int] = field(default_factory=dict)
    link_seconds: Dict[str, float] = field(default_factory=dict)
    n_flows: int = 0
    overlapped: bool = True
    timeline: Optional[Dict] = None    # per-flow/drain start-end schedule
    # (JSON-safe; populated by price_migration(collect_timeline=True), the
    # input obs.trace_from_migration lowers into Chrome-trace lanes)

    @property
    def downtime_s(self) -> float:
        return self.overlap_extra_s if self.overlapped else self.serial_s

    def describe(self) -> str:
        per_link = ", ".join(f"{l}={b / 1e6:.1f}MB"
                             for l, b in sorted(self.link_bytes.items()))
        return (f"priced migration: {self.downtime_s:.3f}s downtime "
                f"(serial {self.serial_s:.3f}s, drain {self.drain_s:.3f}s, "
                f"{self.n_flows} flows; {per_link or 'no traffic'})")


def classify_link(old: PlanLayout, src: DeviceId, dst: DeviceId,
                  topo: Topology) -> str:
    """The physical link a (src -> dst) migration byte rides."""
    if src[0] == dst[0] and src[0] in topo.subcluster_names:
        dpn = old.devices_per_node.get(src[0], 1)
        if src[1] // dpn == dst[1] // dpn:
            return f"intra:{src[0]}"
        return f"ib:{src[0]}"
    return CROSS_LINK


def _drain_nodes(old_strategy: ParallelStrategy, old_cluster: HeteroCluster,
                 layers: Sequence[Layer]
                 ) -> Tuple[List[SimNode], Dict[int, Tuple]]:
    """The old plan's final-step tail as netsim nodes: per stage a fixed
    drain delay until its last backward, then its gradient sync on its
    physical link.  Returns (nodes, per-stage release node id)."""
    strat = old_strategy
    res = simulate([s.t_f for s in strat.stages],
                   [s.t_b for s in strat.stages],
                   strat.c_links, strat.n_microbatches, strat.warmup_counts)
    last_b = [0.0] * strat.n_stages
    for node, t0 in res.start.items():
        kind, _, i = node
        if kind == "B" and i < strat.n_stages:
            last_b[i] = max(last_b[i], t0 + res.dur[node])
    bd = collective_breakdown(strat, old_cluster, layers)
    nodes: List[SimNode] = []
    release: Dict[int, Tuple] = {}
    for i in range(strat.n_stages):
        drain_id = ("drain", i)
        nodes.append(SimNode(drain_id, last_b[i]))
        e = bd["stages"][i]
        if e["sync_time_s"] > 0 and e["sync_link"]:
            sync_id = ("sync", i)
            nodes.append(SimNode(sync_id, e["sync_time_s"],
                                 deps=(drain_id,), links=(e["sync_link"],)))
            release[i] = sync_id
        else:
            release[i] = drain_id
    # remaining boundary activation traffic on its physical links
    for i, (c, link) in enumerate(zip(strat.c_links, bd["link_ids"])):
        work = c * strat.n_microbatches
        if work > 0:
            nodes.append(SimNode(("act", i), work, links=(link,)))
    return nodes, release


def _flows(mplan: MigrationPlan, old: PlanLayout, topo: Topology, *,
           restore_bw: float) -> Tuple[List[Tuple], Dict[str, int]]:
    """Aggregate transfers into per-(src, dst, stage) connection flows:
    [(flow_id, links, work_seconds, src_stage | None)], plus per-link byte
    totals.  ``src_stage=None`` flows (checkpoint restores) are releasable
    at t=0."""
    agg: Dict[Tuple, Tuple[float, int]] = {}
    link_bytes: Dict[str, int] = {}
    for t in mplan.transfers:
        stage = old.leaf_stage.get(t.leaf)
        if t.src is None:
            key = (None, t.dst, None)
            link, bw, lat = RESTORE_LINK, restore_bw, 0.0
        else:
            link = classify_link(old, t.src, t.dst, topo)
            try:
                l = topo.link(link)
            except KeyError:            # source sub-cluster left the fleet
                l = topo.cross_link()
                link = l.name
            bw, lat = l.bandwidth, l.latency
            key = (t.src, t.dst, stage)
        work, nb = agg.get(key + (link,), (0.0, 0))
        if nb == 0:
            work += lat                 # per-connection startup, once
        agg[key + (link,)] = (work + t.nbytes / bw, nb + t.nbytes)
        link_bytes[link] = link_bytes.get(link, 0) + t.nbytes
    flows = [(("mig",) + key[:3], (key[3],), work, key[2])
             for key, (work, _) in sorted(agg.items(), key=lambda kv: repr(kv))]
    return flows, link_bytes


def _fmt_dev(d: Optional[DeviceId]) -> Optional[str]:
    return None if d is None else f"{d[0]}:{d[1]}"


def _timeline(flows: List[Tuple], res, drain_nodes: Sequence[SimNode],
              release: Dict[int, Tuple], overlapped: bool) -> Dict:
    """JSON-safe flow/drain schedule from a solved netsim run — the exact
    start/end seconds ``obs.trace_from_migration`` renders as lanes."""
    flow_entries = []
    for fid, links, work, stage in flows:
        if fid not in res.start:
            continue
        src, dst = fid[1], fid[2]
        flow_entries.append({
            "id": f"{_fmt_dev(src) or 'ckpt'}->{_fmt_dev(dst)}"
                  + (f"@s{stage}" if stage is not None else ""),
            "src": _fmt_dev(src), "dst": _fmt_dev(dst), "src_stage": stage,
            "link": links[0], "work_s": work,
            "start_s": res.start[fid], "end_s": res.end[fid]})
    release_ids = set(release.values())
    drain_entries = []
    for node in drain_nodes:
        if node.nid not in res.start:
            continue
        kind = node.nid[0]
        drain_entries.append({
            "id": f"{kind}{node.nid[1]}", "kind": kind,
            "stage": node.nid[1],
            "link": node.links[0] if node.links else None,
            "is_release": node.nid in release_ids,
            "start_s": res.start[node.nid], "end_s": res.end[node.nid]})
    return {"overlapped": overlapped, "flows": flow_entries,
            "drain": drain_entries}


def price_migration(mplan: MigrationPlan, old_layout: PlanLayout,
                    new_cluster: HeteroCluster, *,
                    old_strategy: Optional[ParallelStrategy] = None,
                    old_cluster: Optional[HeteroCluster] = None,
                    layers: Optional[Sequence[Layer]] = None,
                    restore_bw: float = DEFAULT_RESTORE_BW,
                    overlap: bool = True,
                    collect_timeline: bool = False) -> MigrationCost:
    """Price ``mplan`` on ``new_cluster``'s surviving links (module
    docstring).  ``old_strategy``/``old_cluster``/``layers`` enable the
    overlap scheduler; without them (or ``overlap=False``) the cost is the
    stop-the-world serial time.  ``collect_timeline=True`` additionally
    keeps the solved per-flow/per-drain-node schedule (prices are
    unchanged — the same runs are solved either way)."""
    topo = build_topology(new_cluster)
    flows, link_bytes = _flows(mplan, old_layout, topo,
                               restore_bw=restore_bw)
    if not flows:
        return MigrationCost(0.0, 0.0, 0.0, {}, {}, 0,
                             overlapped=overlap,
                             timeline={"overlapped": overlap, "flows": [],
                                       "drain": []}
                             if collect_timeline else None)

    # serial: migration alone, contended only among its own flows
    serial = run([SimNode(fid, work, links=links)
                  for fid, links, work, _ in flows])
    link_seconds = dict(serial.link_busy)

    can_overlap = overlap and old_strategy is not None \
        and old_cluster is not None and layers is not None
    if not can_overlap:
        tl = _timeline(flows, serial, (), {}, False) \
            if collect_timeline else None
        return MigrationCost(serial.makespan, serial.makespan, 0.0,
                             link_bytes, link_seconds, len(flows),
                             overlapped=False, timeline=tl)

    drain_nodes, release = _drain_nodes(old_strategy, old_cluster, layers)
    baseline = run(drain_nodes)
    combined = list(drain_nodes)
    for fid, links, work, stage in flows:
        deps = (release[stage],) if stage in release else ()
        combined.append(SimNode(fid, work, deps=deps, links=links))
    full = run(combined)
    extra = max(0.0, full.makespan - baseline.makespan)
    tl = _timeline(flows, full, drain_nodes, release, True) \
        if collect_timeline else None
    return MigrationCost(serial.makespan, extra, baseline.makespan,
                         link_bytes, link_seconds, len(flows),
                         overlapped=True, timeline=tl)
